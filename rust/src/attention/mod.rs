//! Decode-time attention backends: full (FlashAttention stand-in), SALS,
//! and every baseline the paper compares against (Table 1 / §5.1).
//!
//! All backends implement [`AttentionBackend`]: a per-layer KV store with
//! `append` (new token's pre-RoPE key + value) and `attend` (current
//! pre-RoPE multi-head query → attention output). Each backend meters its
//! cache **memory traffic** (the quantity §4.5's roofline argument is
//! about) and reports resident cache bytes, which drive the Memory-Access
//! and Comp.-ratio columns of Tables 2–4.
//!
//! # Batched-prefill contract
//!
//! Prefill is a matmul-shaped workload, not a repeated decode, so the trait
//! also carries a multi-token path:
//!
//! * [`AttentionBackend::append_batch`]`(ks, vs, n)` — append `n` tokens'
//!   **pre-RoPE** stacked keys/values, both (n, kv_dim) row-major. Row `t`
//!   lands at absolute position `len() + t`; backends apply RoPE (or latent
//!   projection) themselves, batched where they can.
//! * [`AttentionBackend::prefill_attend`]`(qs, n, out)` — causal
//!   multi-token attention for the **last `n` cached tokens**: `qs` is
//!   (n, q_dim) row-major pre-RoPE queries, row `t` has absolute position
//!   `len() - n + t` and attends to cached positions `0..=len() - n + t`.
//!   Masking is the backend's responsibility; callers never pre-rotate.
//! * [`AttentionBackend::forward_batch`]`(ks, vs, qs, n, out)` — the chunk
//!   entry point the model/engine drive: semantically equal to
//!   interleaving `append`/`attend` token-by-token (the provided default
//!   does exactly that, so every backend keeps working). Backends with a
//!   native batched path override it, typically as
//!   `append_batch` + `prefill_attend`.
//!
//! Traffic metering on the batched path follows the same canonical rules
//! as decode: writes are metered per appended token exactly as `append`
//! would, and reads charge each query row the cost its single-token
//! `attend` would have paid at the same cache length — so Tables 2–4 and
//! the §4.5 roofline stay comparable whichever path produced the numbers.
//!
//! # Cross-sequence batched decode contract
//!
//! The engine also batches *decode* across sequences
//! ([`crate::model::Model::decode_batch`]): the per-token projections of
//! all running sequences are stacked into one (batch, ·) matmul against
//! the shared weights. Attention is NOT batched across sequences — every
//! sequence owns private per-layer backends, so the batched step reaches
//! each backend as the ordinary single-token [`AttentionBackend::append`]
//! + [`AttentionBackend::attend`] pair, identical to scalar decode. What a
//! backend must guarantee (and may rely on):
//!
//! * **Same calls, same order.** A backend cannot distinguish batched from
//!   scalar decode; per-sequence call sequences are identical, so caches,
//!   traffic meters, and `kv_bytes()` evolve identically.
//! * **`Send`, not `Sync`.** Sequences fan out across worker threads, but
//!   each backend is touched by exactly one thread per engine step (the
//!   decode fan-out partitions sequences into disjoint per-worker blocks).
//!   Interior state needs no synchronization.
//! * **No cross-sequence state.** Anything shared between sequences (the
//!   SALS projector, quantization tables) must be immutable or cloned per
//!   backend — concurrent decode of many sequences reads it from many
//!   threads at once.
//!
//! # Prefix fork/adopt contract
//!
//! Shared-prefix KV reuse ([`crate::kvcache::PrefixCache`]) needs the
//! cache *contents* of a prefill to be adoptable by later sequences. The
//! trait carries a snapshot pair for that:
//!
//! * [`AttentionBackend::fork_prefix`]`(n_tokens)` — freeze the current
//!   cache (exactly `n_tokens == len()` tokens, at a prefill-chunk
//!   boundary) into an immutable, refcounted [`PrefixSnapshot`]. Backends
//!   that cannot capture their state exactly return `None` and the caller
//!   simply skips publication.
//! * [`AttentionBackend::adopt_prefix`]`(snap)` — on an **empty** backend
//!   of the same configuration, take the snapshot's tokens by reference.
//!   The binding guarantee: an adopter is **bit-identical** to a backend
//!   cold-prefilled over the same tokens — every later `attend`/
//!   `forward_batch` output, every traffic meter, and `kv_bytes()` agree
//!   exactly. Appends past the boundary go to private storage
//!   (copy-on-write at the snapshot boundary); the shared spans are never
//!   mutated.
//!
//! [`AttentionBackend::kv_bytes`] deliberately *includes* adopted shared
//! bytes (so footprint models and compression ratios need no
//! reuse-awareness); [`AttentionBackend::shared_prefix_bytes`] reports the
//! by-reference portion so pool accounting can charge shared pages once
//! across all adopters. [`SharedVec`] is the storage primitive backends
//! use to hold an immutable shared span plus a private tail in one
//! logical buffer.
//!
//! # Footprint contract: estimation vs metering
//!
//! Two trait surfaces describe cache memory and they must not be confused:
//!
//! * [`AttentionBackend::kv_bytes`] **measures** — the live resident bytes
//!   of this backend's cache right now. It is exact and drives the
//!   Comp.-ratio columns of Tables 2–4 and per-step pool accounting.
//! * [`AttentionBackend::footprint`] **predicts** — a [`FootprintModel`]
//!   giving the resident bytes this backend *will* occupy once grown to
//!   some length. Admission control needs that number before a single
//!   token exists, so the model must be derivable from a freshly
//!   constructed (empty) backend: configuration only, no cache state.
//!
//! The contract binding them: for a sequence grown to `L` tokens,
//! `footprint().bytes_at(L)` tracks `kv_bytes()` within ~25% (asserted in
//! `tests/footprint.rs` for every backend family). Models may over-estimate
//! short sequences (fixed terms like rings and quant-store windows are
//! charged up front) — that only makes admission conservative. Erring high
//! is always safer than erring low: an under-estimate turns into preemption
//! churn at the engine, not a correctness bug, but it defeats the purpose
//! of backend-aware admission (the Table-7 capacity gains exist precisely
//! because SALS footprints are honest multiples smaller than dense fp32).
//!
//! Both surfaces describe the *modeled* cache, which for most backends is
//! also the physical allocation. Known exception: StreamingLLM meters (and
//! therefore predicts) its post-eviction live set — sink + recent — while
//! this CPU reference keeps the dense rows resident (see the note in
//! `baselines/streaming_llm.rs`); a production port that admits against
//! that model must actually evict.
//!
//! # Decode hot-path contract: shared kernels, zero allocation
//!
//! Every sparse backend's `append`/`attend` pair runs per (layer, token)
//! at decode time, so the path is held to three rules:
//!
//! * **Shared packed kernels.** Token scoring is a unit-stride
//!   [`crate::tensor::ops::matmul_tn`] over a contiguous scoring panel
//!   (SALS stores its latents split at r* for exactly this — see
//!   `sals.rs`); selection merge is [`merge_selection_into`]; the exact
//!   attention epilogue is [`crate::tensor::ops::sparse_attend`] for the
//!   materialized-panel backends and the tile-streaming
//!   [`crate::tensor::ops::fused_sparse_attend`] for SALS (§4.4 —
//!   reconstruct·RoPE·QKᵀ fused, key panel never materialized); quantized
//!   value reads go through the page-coherent
//!   [`crate::quant::TokenQuantStore::gather_rows`] /
//!   [`crate::quant::TokenQuantStore::gather_rows_cols`].
//! * **Zero per-call heap allocation.** All per-token buffers (rotated
//!   query, pooled query, scores, top-k indices, merged selection,
//!   gathered K/V panels, kernel scratch) are backend-owned and grow to a
//!   high-water mark; steady-state decode never allocates. Baselines share
//!   `baselines::common::BaselineScratch` for this. (Parallel attend fans
//!   out through the engine's persistent
//!   [`crate::util::threadpool::WorkerPool`] — per-call dispatch is a
//!   slot write + epoch bump, no thread spawn and no allocation.)
//! * **Thread-invariant parallelism.** Intra-attend fan-out (the
//!   [`AttentionBackend::set_workers`] handle) partitions by KV head, by
//!   fixed token blocks, and by fixed-length split-KV selection segments
//!   — units whose arithmetic does not depend on which worker (or how
//!   many) runs them, merged in fixed order — so decode output is
//!   bit-identical at every worker-handle width and pool size.
//!
//! Traffic metering stays canonical under the shared kernels: scoring
//! meters exactly the panel bytes it scans (`len·r*` f32 for SALS — not
//! the full `len·r` rows), and quantized gathers meter per-row payload
//! plus each touched page's scale/zero params **once per page**
//! ([`crate::quant::TokenQuantStore::gather_read_bytes`]), so the BENCH
//! tables reflect the bytes the layout actually streams.

pub mod full;
pub mod sals;
pub mod traffic;

pub mod baselines {
    pub mod common;
    pub mod double_sparse;
    pub mod hshare;
    pub mod kivi;
    pub mod loki;
    pub mod palu;
    pub mod quest;
    pub mod streaming_llm;
}

pub use full::FullAttention;
pub use sals::{PrefillSparsity, SalsAttention, SalsConfig, SalsStageTimes, PREFILL_SPARSE_MIN_LEN};
pub use traffic::Traffic;

use crate::util::threadpool::Workers;
use std::any::Any;
use std::ops::Index;
use std::sync::Arc;

/// An f32 buffer whose leading span may be held **by reference** to an
/// immutable shared prefix (an `Arc<[f32]>` published by another
/// sequence's [`AttentionBackend::fork_prefix`]) while appends land in a
/// private tail — the storage primitive behind prefix reuse's
/// copy-on-write boundary. Logical indexing is over the concatenation
/// `shared ++ own`; the shared span is never mutated.
///
/// Backends align the boundary to a whole number of rows (tokens ×
/// row-width), so per-row reads ([`SharedVec::row`]) never straddle it
/// and segmented kernels ([`crate::tensor::ops::causal_attend_chunk_seg`])
/// consume [`SharedVec::segs`] directly.
#[derive(Clone, Debug, Default)]
pub struct SharedVec {
    shared: Option<Arc<[f32]>>,
    own: Vec<f32>,
}

impl SharedVec {
    pub fn new() -> SharedVec {
        SharedVec::default()
    }

    /// A vector whose entire current content is the shared span.
    pub fn from_shared(shared: Arc<[f32]>) -> SharedVec {
        SharedVec { shared: Some(shared), own: Vec::new() }
    }

    /// Logical element count (shared + own).
    pub fn len(&self) -> usize {
        self.shared_len() + self.own.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Elements held by reference to the shared prefix.
    pub fn shared_len(&self) -> usize {
        self.shared.as_ref().map_or(0, |s| s.len())
    }

    /// Resident bytes of the by-reference span.
    pub fn shared_bytes(&self) -> usize {
        self.shared_len() * 4
    }

    /// Append to the private tail.
    pub fn extend_from_slice(&mut self, xs: &[f32]) {
        self.own.extend_from_slice(xs);
    }

    /// Mutable view of the last `n` elements — which must all be private
    /// (in-place RoPE after an append never reaches the shared span).
    pub fn tail_mut(&mut self, n: usize) -> &mut [f32] {
        let m = self.own.len();
        assert!(n <= m, "tail_mut({n}) reaches into the shared prefix ({m} private)");
        &mut self.own[m - n..]
    }

    /// Contiguous view of logical elements `lo..hi`; panics if the range
    /// straddles the shared/own boundary (row-aligned boundaries make
    /// per-row reads safe by construction).
    pub fn slice(&self, lo: usize, hi: usize) -> &[f32] {
        assert!(lo <= hi && hi <= self.len(), "slice {lo}..{hi} of {}", self.len());
        let ns = self.shared_len();
        if lo >= ns {
            &self.own[lo - ns..hi - ns]
        } else if hi <= ns {
            &self.shared.as_ref().unwrap()[lo..hi]
        } else {
            panic!("slice {lo}..{hi} straddles the shared boundary at {ns}")
        }
    }

    /// Row view: logical elements `start..start + w`.
    pub fn row(&self, start: usize, w: usize) -> &[f32] {
        self.slice(start, start + w)
    }

    /// The two storage segments, shared first (either may be empty) —
    /// feed directly to segment-aware kernels.
    pub fn segs(&self) -> [&[f32]; 2] {
        [self.shared.as_deref().unwrap_or(&[]), &self.own]
    }

    /// [`SharedVec::segs`] truncated to the first `end` logical elements.
    pub fn segs_to(&self, end: usize) -> [&[f32]; 2] {
        assert!(end <= self.len());
        let ns = self.shared_len();
        let a = self.shared.as_deref().unwrap_or(&[]);
        if end <= ns {
            [&a[..end], &[]]
        } else {
            [a, &self.own[..end - ns]]
        }
    }

    /// Freeze the full current contents as an `Arc` for publication. A
    /// pure adopter (no private tail) reuses its existing `Arc`, so
    /// re-forking an adopted prefix copies nothing.
    pub fn fork_arc(&self) -> Arc<[f32]> {
        match (&self.shared, self.own.is_empty()) {
            (Some(s), true) => Arc::clone(s),
            _ => {
                let mut v = Vec::with_capacity(self.len());
                v.extend_from_slice(self.shared.as_deref().unwrap_or(&[]));
                v.extend_from_slice(&self.own);
                Arc::from(v)
            }
        }
    }

    /// Iterate logical elements in order.
    pub fn iter(&self) -> impl Iterator<Item = &f32> {
        self.shared.as_deref().unwrap_or(&[]).iter().chain(self.own.iter())
    }

    /// Copy out the logical contents.
    pub fn to_vec(&self) -> Vec<f32> {
        self.iter().copied().collect()
    }
}

impl Index<usize> for SharedVec {
    type Output = f32;
    fn index(&self, i: usize) -> &f32 {
        let ns = self.shared_len();
        if i < ns {
            &self.shared.as_ref().unwrap()[i]
        } else {
            &self.own[i - ns]
        }
    }
}

impl PartialEq for SharedVec {
    /// Logical-content equality — where the shared boundary sits is a
    /// storage detail, not part of the value.
    fn eq(&self, other: &SharedVec) -> bool {
        self.len() == other.len() && self.iter().zip(other.iter()).all(|(a, b)| a == b)
    }
}

/// Type-erased immutable capture of one backend layer's cache at a
/// chunk-aligned token boundary, produced by
/// [`AttentionBackend::fork_prefix`] and consumed by
/// [`AttentionBackend::adopt_prefix`]. Cloning is cheap (refcount bumps);
/// the payload is backend-specific and adopters downcast it.
#[derive(Clone)]
pub struct PrefixSnapshot {
    /// Tokens the snapshot freezes (== the donor's `len()` at fork time).
    pub n_tokens: usize,
    /// Resident bytes adopters will hold *by reference* (the refcounted
    /// panels/pages — per-adopter private copies like fp32 rings are
    /// excluded). Pool accounting charges these once across adopters.
    pub shared_bytes: usize,
    /// Backend-specific payload.
    pub data: Arc<dyn Any + Send + Sync>,
}

impl std::fmt::Debug for PrefixSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrefixSnapshot")
            .field("n_tokens", &self.n_tokens)
            .field("shared_bytes", &self.shared_bytes)
            .finish_non_exhaustive()
    }
}

/// Shape parameters of one attention layer.
#[derive(Clone, Copy, Debug)]
pub struct AttnShape {
    /// Query heads.
    pub n_heads: usize,
    /// KV heads (== n_heads for MHA; fewer for GQA).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Maximum sequence length (RoPE table size).
    pub max_seq: usize,
    /// RoPE base (10_000 for LLaMA2/Mistral, 500_000 for LLaMA3).
    pub rope_base: f32,
}

impl AttnShape {
    /// MHA shape helper.
    pub fn mha(n_heads: usize, head_dim: usize, max_seq: usize) -> AttnShape {
        AttnShape { n_heads, n_kv_heads: n_heads, head_dim, max_seq, rope_base: 10_000.0 }
    }

    /// GQA shape helper.
    pub fn gqa(n_heads: usize, n_kv_heads: usize, head_dim: usize, max_seq: usize) -> AttnShape {
        assert_eq!(n_heads % n_kv_heads, 0);
        AttnShape { n_heads, n_kv_heads, head_dim, max_seq, rope_base: 10_000.0 }
    }

    /// Stacked query dimension (n_heads * head_dim).
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Stacked key/value dimension (n_kv_heads * head_dim).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
}

/// Affine prediction of one backend's resident cache size:
/// `fixed_bytes + bytes_per_token · min(tokens, cap_tokens)`.
///
/// See the module-level *Footprint contract* section: this struct
/// **predicts** (admission), [`AttentionBackend::kv_bytes`] **measures**
/// (metering). A model is built from backend configuration alone, so the
/// engine can price a request against any backend family without
/// instantiating a sequence.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FootprintModel {
    /// Length-independent resident bytes: pre-allocated rings, plus the
    /// expected steady-state excess of quantized stores' fp32 tails over
    /// their frozen rate.
    pub fixed_bytes: usize,
    /// Marginal resident bytes per cached token (asymptotic rate).
    pub bytes_per_token: usize,
    /// Token count beyond which the cache stops growing (bounded caches
    /// like StreamingLLM's sink+recent window); `None` = grows with the
    /// sequence.
    pub cap_tokens: Option<usize>,
}

impl FootprintModel {
    /// Unbounded affine model.
    pub fn linear(fixed_bytes: usize, bytes_per_token: usize) -> FootprintModel {
        FootprintModel { fixed_bytes, bytes_per_token, cap_tokens: None }
    }

    /// Predicted resident cache bytes at `tokens` total cached tokens.
    pub fn bytes_at(&self, tokens: usize) -> usize {
        let t = self.cap_tokens.map_or(tokens, |c| tokens.min(c));
        self.fixed_bytes + self.bytes_per_token * t
    }
}

/// A per-layer decode-attention backend with an internal KV store.
pub trait AttentionBackend {
    /// Append the new token's **pre-RoPE** stacked key and value
    /// (both length kv_dim). Position is the running token count.
    fn append(&mut self, k: &[f32], v: &[f32]);

    /// Attend with the current token's **pre-RoPE** stacked query
    /// (length q_dim); the query's position is `len() - 1` (its KV was
    /// appended first, mirroring standard decode). Returns (q_dim) output.
    fn attend(&mut self, q: &[f32], out: &mut [f32]);

    /// Append `n` tokens' pre-RoPE keys/values ((n, kv_dim) row-major
    /// each); row `t` lands at position `len() + t`. Default loops
    /// [`AttentionBackend::append`]; backends override for batched RoPE /
    /// batched latent projection.
    fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize) {
        assert!(n > 0, "append_batch of empty chunk");
        assert_eq!(ks.len(), vs.len());
        assert_eq!(ks.len() % n, 0);
        let kvd = ks.len() / n;
        for t in 0..n {
            self.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
        }
    }

    /// Causal multi-token attention for the last `n` cached tokens: `qs`
    /// is (n, q_dim) pre-RoPE, row `t` has position `len() - n + t` and
    /// sees positions `0..=len() - n + t`. `out` is (n, q_dim).
    ///
    /// The default handles only `n == 1` (a plain [`AttentionBackend::attend`]):
    /// with the whole chunk already appended, the single-token methods
    /// cannot mask the chunk's later keys, so backends without a native
    /// implementation are driven through [`AttentionBackend::forward_batch`]'s
    /// interleaved default instead — callers should prefer `forward_batch`
    /// unless they know the backend overrides this.
    fn prefill_attend(&mut self, qs: &[f32], n: usize, out: &mut [f32]) {
        assert_eq!(
            n,
            1,
            "{}: no native batched prefill_attend; drive chunks through forward_batch()",
            self.name()
        );
        self.attend(qs, out);
    }

    /// Process one prefill chunk: append `n` tokens' KV and produce every
    /// token's causal attention output ((n, q_dim) into `out`).
    /// Semantically identical to interleaving `append`/`attend` per token,
    /// which is exactly what this default does — so every backend works
    /// unbatched. Backends with batched kernels override this (typically
    /// `append_batch` + `prefill_attend`).
    fn forward_batch(&mut self, ks: &[f32], vs: &[f32], qs: &[f32], n: usize, out: &mut [f32]) {
        assert!(n > 0, "forward_batch of empty chunk");
        assert_eq!(ks.len(), vs.len());
        assert_eq!(ks.len() % n, 0);
        assert_eq!(qs.len() % n, 0);
        assert_eq!(out.len(), qs.len());
        let kvd = ks.len() / n;
        let qd = qs.len() / n;
        for t in 0..n {
            self.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
            self.attend(&qs[t * qd..(t + 1) * qd], &mut out[t * qd..(t + 1) * qd]);
        }
    }

    /// Notification that prefill is complete and the sequence transitions
    /// to decode: drop any chunk-sized scratch (key/value panels, score
    /// tiles) that decode will never touch, so long-lived sequences don't
    /// pin prefill-sized buffers through their whole decode phase.
    /// Default no-op.
    fn end_prefill(&mut self) {}

    /// Freeze the current cache into an immutable, refcounted
    /// [`PrefixSnapshot`] another sequence can adopt (see the module-level
    /// *Prefix fork/adopt contract*). Only a full capture is supported:
    /// callers pass `n_tokens == len()` at a prefill-chunk boundary.
    /// Backends return `None` when they cannot freeze their state exactly
    /// (no fork support, or transient prefill-only state that an adopter
    /// could not reproduce) — callers then skip publication. Default: no
    /// fork support.
    fn fork_prefix(&self, _n_tokens: usize) -> Option<PrefixSnapshot> {
        None
    }

    /// Adopt a snapshot produced by [`AttentionBackend::fork_prefix`] on a
    /// backend of the same configuration. Must be called on an **empty**
    /// backend. Returns `false` when the payload is foreign or adoption is
    /// unsupported (callers fall back to cold prefill). On success the
    /// backend is bit-identical — outputs, traffic meters, `kv_bytes()` —
    /// to one cold-prefilled over the snapshot's tokens, with the
    /// refcounted spans held by reference. Default: unsupported.
    fn adopt_prefix(&mut self, _snap: &PrefixSnapshot) -> bool {
        false
    }

    /// Resident bytes currently held *by reference* to an adopted shared
    /// prefix. Included in [`AttentionBackend::kv_bytes`] (adopters meter
    /// like cold sequences); the engine subtracts this when charging the
    /// pool so shared pages are paid for once. Default 0.
    fn shared_prefix_bytes(&self) -> usize {
        0
    }

    /// Worker handle for *intra-attend* parallelism (per-KV-head panel
    /// fan-out, token-block score scans, split-KV segments). The engine
    /// lends each sequence a [`Workers`] share of its persistent pool —
    /// batch-1 long-context decode is exactly where a single sequence
    /// should own the whole fan-out. Contract: the handle is a
    /// *scheduling* knob only — outputs, traffic meters, and `kv_bytes()`
    /// must be bit-identical for every width and backing pool size (the
    /// shared kernels partition by KV head / fixed token blocks /
    /// fixed-length selection segments, whose per-unit arithmetic and
    /// merge order are worker-invariant). Backends may clamp or ignore
    /// it; default no-op (serial).
    fn set_workers(&mut self, _workers: &Workers) {}

    /// Number of cached tokens.
    fn len(&self) -> usize;

    /// True if no tokens are cached.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cumulative cache memory traffic since construction.
    fn traffic(&self) -> Traffic;

    /// Resident KV-cache bytes at the current length (metering — see the
    /// module-level *Footprint contract*).
    fn kv_bytes(&self) -> usize;

    /// Predicted resident-cache model for this backend (estimation — see
    /// the module-level *Footprint contract*). Must be answerable on a
    /// freshly constructed backend: configuration only, independent of how
    /// many tokens are currently cached.
    fn footprint(&self) -> FootprintModel;

    /// Human-readable method name for reports.
    fn name(&self) -> &'static str;
}

/// Naive exact per-head attention over an explicit (post-RoPE) K/V token
/// subset (Eq. 5) — the **reference implementation** the parity tests
/// compare against. Production decode goes through the packed
/// [`crate::tensor::ops::sparse_attend`] kernel instead (panel packing,
/// matmul-shaped QKᵀ/PV, caller-owned scratch); this strided dot/axpy
/// version is kept only to pin the kernel's semantics in tests.
#[cfg(test)]
pub(crate) fn exact_attention(
    shape: &AttnShape,
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    n_sel: usize,
    out: &mut [f32],
) {
    let d = shape.head_dim;
    let kvd = shape.kv_dim();
    let scale = 1.0 / (d as f32).sqrt();
    let group = shape.group_size();
    let mut scores = vec![0.0f32; n_sel];
    out.fill(0.0);
    for h in 0..shape.n_heads {
        let kvh = h / group;
        let qh = &q[h * d..(h + 1) * d];
        for (j, s) in scores.iter_mut().enumerate() {
            let krow = &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
            *s = crate::tensor::ops::dot(qh, krow) * scale;
        }
        crate::tensor::ops::softmax(&mut scores);
        let oh = &mut out[h * d..(h + 1) * d];
        for (j, &p) in scores.iter().enumerate() {
            let vrow = &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
            crate::tensor::ops::axpy(p, vrow, oh);
        }
    }
}

/// Merge sink tokens, a recent window, and selected critical indices into a
/// sorted, deduplicated index set (the paper's x sink + y critical + z
/// recent composition, §5.2). Allocates; decode hot paths use
/// [`merge_selection_into`] with backend-owned scratch.
pub fn merge_selection(
    seq_len: usize,
    sink: usize,
    recent: usize,
    critical: &[usize],
) -> Vec<usize> {
    let mut crit_scratch = Vec::new();
    let mut out = Vec::new();
    merge_selection_into(seq_len, sink, recent, critical, &mut crit_scratch, &mut out);
    out
}

/// Allocation-free [`merge_selection`]: `crit_scratch` and `out` are
/// backend-owned buffers reused across calls (cleared here, capacity
/// retained). Unlike the original mask-based merge this is
/// O(|critical|·log|critical| + |selection|), **not** O(seq_len) — the
/// selection stage no longer touches a sequence-length mask per
/// (layer, token) call: sink and recent are contiguous ranges, so sorting
/// the critical indices and emitting the three ranges in order produces
/// the same sorted, deduplicated set.
pub fn merge_selection_into(
    seq_len: usize,
    sink: usize,
    recent: usize,
    critical: &[usize],
    crit_scratch: &mut Vec<usize>,
    out: &mut Vec<usize>,
) {
    let sink_end = sink.min(seq_len);
    let recent_lo = seq_len.saturating_sub(recent);
    crit_scratch.clear();
    crit_scratch.extend(critical.iter().copied().filter(|&i| i >= sink_end && i < recent_lo));
    crit_scratch.sort_unstable();
    crit_scratch.dedup();
    out.clear();
    out.extend(0..sink_end);
    out.extend_from_slice(crit_scratch);
    out.extend(recent_lo.max(sink_end)..seq_len);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Delegates the required single-token methods to FullAttention but
    /// inherits every batched default — the "any old backend" stand-in.
    struct LoopBackend(FullAttention);

    impl AttentionBackend for LoopBackend {
        fn append(&mut self, k: &[f32], v: &[f32]) {
            self.0.append(k, v)
        }
        fn attend(&mut self, q: &[f32], out: &mut [f32]) {
            self.0.attend(q, out)
        }
        fn len(&self) -> usize {
            self.0.len()
        }
        fn traffic(&self) -> Traffic {
            self.0.traffic()
        }
        fn kv_bytes(&self) -> usize {
            self.0.kv_bytes()
        }
        fn footprint(&self) -> FootprintModel {
            self.0.footprint()
        }
        fn name(&self) -> &'static str {
            "loop"
        }
    }

    #[test]
    fn default_forward_batch_matches_native_blocked_path() {
        // The interleaved default (single-token loop) and FullAttention's
        // blocked override must agree on every chunk position.
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(91);
        let mut native = FullAttention::new(shape);
        let mut looped = LoopBackend(FullAttention::new(shape));
        // A pre-existing prefix so the chunk doesn't start at position 0.
        for _ in 0..7 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            native.append(&k, &v);
            looped.append(&k, &v);
        }
        let n = 19; // > one query tile
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut o1 = vec![0.0f32; n * qd];
        let mut o2 = vec![0.0f32; n * qd];
        native.forward_batch(&ks, &vs, &qs, n, &mut o1);
        looped.forward_batch(&ks, &vs, &qs, n, &mut o2);
        assert_eq!(native.len(), looped.len());
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn default_append_batch_matches_append_loop() {
        let shape = AttnShape::mha(2, 8, 64);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(93);
        let ks = rng.normal_vec(5 * kvd, 1.0);
        let vs = rng.normal_vec(5 * kvd, 1.0);
        let mut a = LoopBackend(FullAttention::new(shape));
        let mut b = FullAttention::new(shape);
        a.append_batch(&ks, &vs, 5);
        for t in 0..5 {
            b.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
        }
        assert_eq!(a.len(), 5);
        // Same cache contents -> same attend output.
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut o1 = vec![0.0f32; shape.q_dim()];
        let mut o2 = vec![0.0f32; shape.q_dim()];
        a.attend(&q, &mut o1);
        b.attend(&q, &mut o2);
        for (x, y) in o1.iter().zip(&o2) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn shared_vec_segments_and_indexing() {
        let mut v = SharedVec::new();
        v.extend_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let arc = v.fork_arc();
        let mut w = SharedVec::from_shared(arc);
        w.extend_from_slice(&[5.0, 6.0]);
        assert_eq!(w.len(), 6);
        assert_eq!(w.shared_len(), 4);
        assert_eq!(w.shared_bytes(), 16);
        assert_eq!(w[0], 1.0);
        assert_eq!(w[4], 5.0);
        assert_eq!(w.row(2, 2), &[3.0, 4.0]);
        assert_eq!(w.row(4, 2), &[5.0, 6.0]);
        let [a, b] = w.segs();
        assert_eq!(a, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b, &[5.0, 6.0]);
        let [a, b] = w.segs_to(3);
        assert_eq!(a, &[1.0, 2.0, 3.0]);
        assert!(b.is_empty());
        let [a, b] = w.segs_to(5);
        assert_eq!(a.len(), 4);
        assert_eq!(b, &[5.0]);
        // Logical equality ignores where the boundary sits.
        let mut flat = SharedVec::new();
        flat.extend_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(w, flat);
        assert_eq!(w.to_vec(), flat.to_vec());
        // tail_mut stays inside the private tail.
        w.tail_mut(2)[0] = 50.0;
        assert_eq!(w[4], 50.0);
        assert_ne!(w, flat);
    }

    #[test]
    #[should_panic(expected = "straddles")]
    fn shared_vec_slice_across_boundary_panics() {
        let mut v = SharedVec::new();
        v.extend_from_slice(&[1.0, 2.0]);
        let mut w = SharedVec::from_shared(v.fork_arc());
        w.extend_from_slice(&[3.0]);
        w.slice(1, 3);
    }

    #[test]
    #[should_panic(expected = "shared prefix")]
    fn shared_vec_tail_mut_into_shared_panics() {
        let mut v = SharedVec::new();
        v.extend_from_slice(&[1.0, 2.0]);
        let mut w = SharedVec::from_shared(v.fork_arc());
        w.extend_from_slice(&[3.0]);
        w.tail_mut(2);
    }

    #[test]
    fn shared_vec_refork_reuses_arc() {
        let mut v = SharedVec::new();
        v.extend_from_slice(&[1.0, 2.0, 3.0]);
        let arc = v.fork_arc();
        let w = SharedVec::from_shared(Arc::clone(&arc));
        // Pure adopter: refork is the same allocation, no copy.
        assert!(Arc::ptr_eq(&arc, &w.fork_arc()));
        // A private tail forces materialization.
        let mut x = SharedVec::from_shared(arc);
        x.extend_from_slice(&[4.0]);
        assert_eq!(x.fork_arc()[..], [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn merge_selection_dedups_and_sorts() {
        let sel = merge_selection(10, 2, 3, &[5, 1, 7, 7]);
        assert_eq!(sel, vec![0, 1, 5, 7, 8, 9]);
    }

    #[test]
    fn merge_selection_small_seq() {
        let sel = merge_selection(2, 4, 4, &[9]);
        assert_eq!(sel, vec![0, 1]);
    }

    #[test]
    fn merge_selection_into_reuses_buffers_and_matches_mask_semantics() {
        // Reference: the original O(seq_len) mask-based merge.
        fn mask_merge(seq_len: usize, sink: usize, recent: usize, critical: &[usize]) -> Vec<usize> {
            let mut mask = vec![false; seq_len];
            for i in 0..sink.min(seq_len) {
                mask[i] = true;
            }
            for i in seq_len.saturating_sub(recent)..seq_len {
                mask[i] = true;
            }
            for &i in critical {
                if i < seq_len {
                    mask[i] = true;
                }
            }
            mask.iter().enumerate().filter_map(|(i, &m)| m.then_some(i)).collect()
        }
        let mut crit_scratch = Vec::new();
        let mut out = Vec::new();
        let cases: [(usize, usize, usize, &[usize]); 6] = [
            (10, 2, 3, &[5, 1, 7, 7, 99]),
            (1, 0, 0, &[0]),
            (50, 4, 8, &[49, 0, 25, 25, 3, 41]),
            (8, 8, 8, &[2]),
            (20, 0, 0, &[]),
            (20, 3, 20, &[10]),
        ];
        for (s, sink, recent, crit) in cases {
            merge_selection_into(s, sink, recent, crit, &mut crit_scratch, &mut out);
            assert_eq!(out, mask_merge(s, sink, recent, crit), "s={s} sink={sink} recent={recent}");
        }
    }

    #[test]
    fn footprint_model_caps_and_accumulates() {
        let unbounded = FootprintModel::linear(100, 8);
        assert_eq!(unbounded.bytes_at(0), 100);
        assert_eq!(unbounded.bytes_at(50), 100 + 400);
        let capped = FootprintModel { fixed_bytes: 0, bytes_per_token: 8, cap_tokens: Some(10) };
        assert_eq!(capped.bytes_at(4), 32);
        assert_eq!(capped.bytes_at(10_000), 80, "bounded caches stop growing");
    }

    #[test]
    fn shape_helpers() {
        let s = AttnShape::gqa(8, 2, 16, 128);
        assert_eq!(s.q_dim(), 128);
        assert_eq!(s.kv_dim(), 32);
        assert_eq!(s.group_size(), 4);
    }

    #[test]
    fn exact_attention_single_token_returns_value() {
        // One cached token: softmax over a singleton is 1 -> out == value.
        let shape = AttnShape::mha(2, 4, 8);
        let q = vec![0.3f32; 8];
        let k = vec![0.1f32; 8];
        let v: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 8];
        exact_attention(&shape, &q, &k, &v, 1, &mut out);
        assert_eq!(out, v);
    }

    #[test]
    fn exact_attention_gqa_maps_heads() {
        // 2 query heads share 1 kv head; identical q halves -> identical out.
        let shape = AttnShape::gqa(2, 1, 4, 8);
        let q = [vec![0.5f32; 4], vec![0.5f32; 4]].concat();
        let k = vec![0.2f32; 8]; // 2 tokens × kv_dim 4
        let v = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let mut out = vec![0.0f32; 8];
        exact_attention(&shape, &q, &k, &v, 2, &mut out);
        assert_eq!(&out[..4], &out[4..]);
    }
}

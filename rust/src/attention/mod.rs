//! Decode-time attention backends: full (FlashAttention stand-in), SALS,
//! and every baseline the paper compares against (Table 1 / §5.1).
//!
//! All backends implement [`AttentionBackend`]: a per-layer KV store with
//! `append` (new token's pre-RoPE key + value) and `attend` (current
//! pre-RoPE multi-head query → attention output). Each backend meters its
//! cache **memory traffic** (the quantity §4.5's roofline argument is
//! about) and reports resident cache bytes, which drive the Memory-Access
//! and Comp.-ratio columns of Tables 2–4.

pub mod full;
pub mod sals;
pub mod traffic;

pub mod baselines {
    pub mod common;
    pub mod double_sparse;
    pub mod hshare;
    pub mod kivi;
    pub mod loki;
    pub mod palu;
    pub mod quest;
    pub mod streaming_llm;
}

pub use full::FullAttention;
pub use sals::{SalsAttention, SalsConfig};
pub use traffic::Traffic;

/// Shape parameters of one attention layer.
#[derive(Clone, Copy, Debug)]
pub struct AttnShape {
    /// Query heads.
    pub n_heads: usize,
    /// KV heads (== n_heads for MHA; fewer for GQA).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Maximum sequence length (RoPE table size).
    pub max_seq: usize,
    /// RoPE base (10_000 for LLaMA2/Mistral, 500_000 for LLaMA3).
    pub rope_base: f32,
}

impl AttnShape {
    /// MHA shape helper.
    pub fn mha(n_heads: usize, head_dim: usize, max_seq: usize) -> AttnShape {
        AttnShape { n_heads, n_kv_heads: n_heads, head_dim, max_seq, rope_base: 10_000.0 }
    }

    /// GQA shape helper.
    pub fn gqa(n_heads: usize, n_kv_heads: usize, head_dim: usize, max_seq: usize) -> AttnShape {
        assert_eq!(n_heads % n_kv_heads, 0);
        AttnShape { n_heads, n_kv_heads, head_dim, max_seq, rope_base: 10_000.0 }
    }

    /// Stacked query dimension (n_heads * head_dim).
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Stacked key/value dimension (n_kv_heads * head_dim).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
}

/// A per-layer decode-attention backend with an internal KV store.
pub trait AttentionBackend {
    /// Append the new token's **pre-RoPE** stacked key and value
    /// (both length kv_dim). Position is the running token count.
    fn append(&mut self, k: &[f32], v: &[f32]);

    /// Attend with the current token's **pre-RoPE** stacked query
    /// (length q_dim); the query's position is `len() - 1` (its KV was
    /// appended first, mirroring standard decode). Returns (q_dim) output.
    fn attend(&mut self, q: &[f32], out: &mut [f32]);

    /// Number of cached tokens.
    fn len(&self) -> usize;

    /// True if no tokens are cached.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cumulative cache memory traffic since construction.
    fn traffic(&self) -> Traffic;

    /// Resident KV-cache bytes at the current length.
    fn kv_bytes(&self) -> usize;

    /// Human-readable method name for reports.
    fn name(&self) -> &'static str;
}

/// Exact per-head attention over an explicit (post-RoPE) K/V token subset —
/// the shared "exact sparse attention" epilogue (Eq. 5). `keys`/`values` are
/// (n_sel, kv_dim) row-major; `q` is post-RoPE (q_dim). Output accumulates
/// into `out` (q_dim). Returns nothing; caller meters traffic.
pub(crate) fn exact_attention(
    shape: &AttnShape,
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    n_sel: usize,
    out: &mut [f32],
) {
    let d = shape.head_dim;
    let kvd = shape.kv_dim();
    let scale = 1.0 / (d as f32).sqrt();
    let group = shape.group_size();
    let mut scores = vec![0.0f32; n_sel];
    out.fill(0.0);
    for h in 0..shape.n_heads {
        let kvh = h / group;
        let qh = &q[h * d..(h + 1) * d];
        for (j, s) in scores.iter_mut().enumerate() {
            let krow = &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
            *s = crate::tensor::ops::dot(qh, krow) * scale;
        }
        crate::tensor::ops::softmax(&mut scores);
        let oh = &mut out[h * d..(h + 1) * d];
        for (j, &p) in scores.iter().enumerate() {
            let vrow = &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
            crate::tensor::ops::axpy(p, vrow, oh);
        }
    }
}

/// Merge sink tokens, a recent window, and selected critical indices into a
/// sorted, deduplicated index set (the paper's x sink + y critical + z
/// recent composition, §5.2).
pub fn merge_selection(
    seq_len: usize,
    sink: usize,
    recent: usize,
    critical: &[usize],
) -> Vec<usize> {
    let mut mask = vec![false; seq_len];
    for i in 0..sink.min(seq_len) {
        mask[i] = true;
    }
    for i in seq_len.saturating_sub(recent)..seq_len {
        mask[i] = true;
    }
    for &i in critical {
        if i < seq_len {
            mask[i] = true;
        }
    }
    mask.iter().enumerate().filter_map(|(i, &m)| if m { Some(i) } else { None }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_selection_dedups_and_sorts() {
        let sel = merge_selection(10, 2, 3, &[5, 1, 7, 7]);
        assert_eq!(sel, vec![0, 1, 5, 7, 8, 9]);
    }

    #[test]
    fn merge_selection_small_seq() {
        let sel = merge_selection(2, 4, 4, &[9]);
        assert_eq!(sel, vec![0, 1]);
    }

    #[test]
    fn shape_helpers() {
        let s = AttnShape::gqa(8, 2, 16, 128);
        assert_eq!(s.q_dim(), 128);
        assert_eq!(s.kv_dim(), 32);
        assert_eq!(s.group_size(), 4);
    }

    #[test]
    fn exact_attention_single_token_returns_value() {
        // One cached token: softmax over a singleton is 1 -> out == value.
        let shape = AttnShape::mha(2, 4, 8);
        let q = vec![0.3f32; 8];
        let k = vec![0.1f32; 8];
        let v: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 8];
        exact_attention(&shape, &q, &k, &v, 1, &mut out);
        assert_eq!(out, v);
    }

    #[test]
    fn exact_attention_gqa_maps_heads() {
        // 2 query heads share 1 kv head; identical q halves -> identical out.
        let shape = AttnShape::gqa(2, 1, 4, 8);
        let q = [vec![0.5f32; 4], vec![0.5f32; 4]].concat();
        let k = vec![0.2f32; 8]; // 2 tokens × kv_dim 4
        let v = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let mut out = vec![0.0f32; 8];
        exact_attention(&shape, &q, &k, &v, 2, &mut out);
        assert_eq!(&out[..4], &out[4..]);
    }
}

//! LLaMA-style decoder forward pass over pluggable attention backends.
//!
//! Weights are shared (`Arc<Weights>`); per-sequence decode state (the KV
//! caches inside each layer's [`AttentionBackend`]) lives in
//! [`SequenceState`]. This split is what lets the coordinator batch many
//! sequences over one weight set, vLLM-style.
//!
//! Two forward paths share the weights:
//!
//! * [`Model::step`] — single-token decode: per-token vectors, `linear`
//!   accumulation loops, streaming attention.
//! * [`Model::forward_batch`] — multi-token prefill chunks: (chunk,
//!   d_model) activation matrices driven through [`crate::tensor::ops::matmul`]
//!   against the weight matrices and through each backend's
//!   `forward_batch`. Prefill is matmul-shaped, so this is where chunked
//!   prefill actually earns its name; [`Model::prefill`] consumes the
//!   whole prompt in chunks of [`Model::PREFILL_CHUNK`].

use super::config::ModelConfig;
use super::weights::Weights;
use crate::attention::AttentionBackend;
use crate::tensor::ops::{matmul, rmsnorm, silu};
use std::sync::Arc;

/// Factory producing one attention backend per layer.
pub type BackendFactory = dyn Fn(usize) -> Box<dyn AttentionBackend + Send> + Send + Sync;

/// Per-sequence decode state: one KV backend per layer + position counter.
pub struct SequenceState {
    pub backends: Vec<Box<dyn AttentionBackend + Send>>,
    pub pos: usize,
}

impl SequenceState {
    pub fn new(cfg: &ModelConfig, factory: &BackendFactory) -> SequenceState {
        SequenceState { backends: (0..cfg.n_layers).map(|l| factory(l)).collect(), pos: 0 }
    }

    /// Total resident KV bytes across layers.
    pub fn kv_bytes(&self) -> usize {
        self.backends.iter().map(|b| b.kv_bytes()).sum()
    }

    /// Prefill finished: let every layer backend drop chunk-sized scratch
    /// before the (long) decode phase.
    pub fn end_prefill(&mut self) {
        for b in &mut self.backends {
            b.end_prefill();
        }
    }

    /// Total cache traffic across layers.
    pub fn traffic(&self) -> crate::attention::Traffic {
        let mut t = crate::attention::Traffic::default();
        for b in &self.backends {
            let bt = b.traffic();
            t.read += bt.read;
            t.written += bt.written;
        }
        t
    }
}

/// The shared model: config + weights. Stateless across sequences.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: Arc<Weights>,
}

/// Scratch buffers for one forward step (reused across steps).
///
/// The `b*` buffers are the batched-prefill activation matrices ((chunk, ·)
/// row-major); they start empty and are grown to the chunk size on first
/// use, so decode-only sequences pay nothing for them.
pub struct Scratch {
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    ffn: Vec<f32>,
    // ---- batched prefill ((chunk, ·) matrices) ----
    bx: Vec<f32>,
    bnormed: Vec<f32>,
    bq: Vec<f32>,
    bk: Vec<f32>,
    bv: Vec<f32>,
    battn: Vec<f32>,
    bproj: Vec<f32>,
    bgate: Vec<f32>,
    bup: Vec<f32>,
    bffn: Vec<f32>,
}

impl Scratch {
    pub fn new(cfg: &ModelConfig) -> Scratch {
        Scratch {
            x: vec![0.0; cfg.d_model],
            normed: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * cfg.head_dim],
            k: vec![0.0; cfg.kv_dim()],
            v: vec![0.0; cfg.kv_dim()],
            attn_out: vec![0.0; cfg.n_heads * cfg.head_dim],
            proj: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.d_ff],
            up: vec![0.0; cfg.d_ff],
            ffn: vec![0.0; cfg.d_model],
            bx: Vec::new(),
            bnormed: Vec::new(),
            bq: Vec::new(),
            bk: Vec::new(),
            bv: Vec::new(),
            battn: Vec::new(),
            bproj: Vec::new(),
            bgate: Vec::new(),
            bup: Vec::new(),
            bffn: Vec::new(),
        }
    }

    /// Release the batched-prefill activation matrices — decode touches
    /// only the single-token buffers, and the `b*` set is chunk-sized
    /// (bgate/bup alone are 2·chunk·d_ff floats), so holding it through a
    /// long decode phase would inflate every running sequence's footprint.
    pub fn end_prefill(&mut self) {
        for buf in [
            &mut self.bx,
            &mut self.bnormed,
            &mut self.bq,
            &mut self.bk,
            &mut self.bv,
            &mut self.battn,
            &mut self.bproj,
            &mut self.bgate,
            &mut self.bup,
            &mut self.bffn,
        ] {
            *buf = Vec::new();
        }
    }

    /// Size the batched buffers for an `n`-token chunk (exact lengths —
    /// the matmul kernels assert full-slice shapes).
    fn ensure_batch(&mut self, cfg: &ModelConfig, n: usize) {
        let d = cfg.d_model;
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_dim();
        self.bx.resize(n * d, 0.0);
        self.bnormed.resize(n * d, 0.0);
        self.bq.resize(n * qd, 0.0);
        self.bk.resize(n * kvd, 0.0);
        self.bv.resize(n * kvd, 0.0);
        self.battn.resize(n * qd, 0.0);
        self.bproj.resize(n * d, 0.0);
        self.bgate.resize(n * cfg.d_ff, 0.0);
        self.bup.resize(n * cfg.d_ff, 0.0);
        self.bffn.resize(n * d, 0.0);
    }
}

/// y = x @ W  for a (d_in, d_out) weight, accumulated into `out`.
fn linear(x: &[f32], w: &crate::tensor::Mat, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.rows);
    debug_assert_eq!(out.len(), w.cols);
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let wrow = &w.data[i * w.cols..(i + 1) * w.cols];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xi * wv;
        }
    }
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Arc<Weights>) -> Model {
        cfg.validate().expect("invalid model config");
        Model { cfg, weights }
    }

    /// One decode step: feed `token`, advance `state`, return logits.
    ///
    /// `process_only`: during prefill we still must append KV and run the
    /// layers (the residual stream feeds later keys), but logits can be
    /// skipped; pass `false` to skip the LM head.
    pub fn step(&self, state: &mut SequenceState, scratch: &mut Scratch, token: usize, want_logits: bool) -> Option<Vec<f32>> {
        let cfg = &self.cfg;
        let w = &self.weights;
        assert!(token < cfg.vocab, "token {token} out of vocab");
        assert!(state.pos < cfg.max_seq, "sequence exceeds max_seq");

        // Embed.
        scratch.x.copy_from_slice(w.embedding.row(token));

        for (layer, lw) in w.layers.iter().enumerate() {
            // ---- attention block ----
            rmsnorm(&scratch.x, &lw.norm_attn, cfg.rms_eps, &mut scratch.normed);
            linear(&scratch.normed, &lw.wq, &mut scratch.q);
            linear(&scratch.normed, &lw.wk, &mut scratch.k);
            linear(&scratch.normed, &lw.wv, &mut scratch.v);
            let backend = &mut state.backends[layer];
            backend.append(&scratch.k, &scratch.v);
            backend.attend(&scratch.q, &mut scratch.attn_out);
            linear(&scratch.attn_out, &lw.wo, &mut scratch.proj);
            for (xi, pi) in scratch.x.iter_mut().zip(&scratch.proj) {
                *xi += pi;
            }
            // ---- FFN block (SwiGLU) ----
            rmsnorm(&scratch.x, &lw.norm_ffn, cfg.rms_eps, &mut scratch.normed);
            linear(&scratch.normed, &lw.w_gate, &mut scratch.gate);
            linear(&scratch.normed, &lw.w_up, &mut scratch.up);
            for (g, u) in scratch.gate.iter_mut().zip(&scratch.up) {
                *g = silu(*g) * u;
            }
            linear(&scratch.gate, &lw.w_down, &mut scratch.ffn);
            for (xi, fi) in scratch.x.iter_mut().zip(&scratch.ffn) {
                *xi += fi;
            }
        }
        state.pos += 1;

        if !want_logits {
            return None;
        }
        // Final norm + tied LM head.
        rmsnorm(&scratch.x, &w.norm_final, cfg.rms_eps, &mut scratch.normed);
        let mut logits = vec![0.0f32; cfg.vocab];
        // logits = E @ normed (E rows are embeddings).
        for (t, l) in logits.iter_mut().enumerate() {
            *l = crate::tensor::ops::dot(w.embedding.row(t), &scratch.normed);
        }
        Some(logits)
    }

    /// Default prefill chunk size (tokens per [`Model::forward_batch`] call)
    /// used by [`Model::prefill`]. Large enough that the per-chunk matmuls
    /// amortize, small enough that activation scratch stays modest.
    pub const PREFILL_CHUNK: usize = 128;

    /// Multi-token chunk forward: feed `tokens`, advance `state` by
    /// `tokens.len()` positions, and return the logits after the last
    /// token if `want_logits`.
    ///
    /// The chunk's activations travel as (n, d) row-major matrices —
    /// rmsnorm per row, QKV/output/FFN projections as single matmuls
    /// against the shared weights, and attention through each layer
    /// backend's `forward_batch` (causal within the chunk). Semantically
    /// equivalent to `n` calls of [`Model::step`]; the arithmetic is
    /// reassociated into blocked kernels, so logits agree to ~1e-5, not
    /// bit-exactly.
    pub fn forward_batch(
        &self,
        state: &mut SequenceState,
        scratch: &mut Scratch,
        tokens: &[usize],
        want_logits: bool,
    ) -> Option<Vec<f32>> {
        let cfg = &self.cfg;
        let w = &self.weights;
        let n = tokens.len();
        assert!(n > 0, "forward_batch of empty chunk");
        assert!(state.pos + n <= cfg.max_seq, "sequence exceeds max_seq");
        let d = cfg.d_model;
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_dim();
        scratch.ensure_batch(cfg, n);

        // Embed the chunk.
        for (t, &tok) in tokens.iter().enumerate() {
            assert!(tok < cfg.vocab, "token {tok} out of vocab");
            scratch.bx[t * d..(t + 1) * d].copy_from_slice(w.embedding.row(tok));
        }

        for (layer, lw) in w.layers.iter().enumerate() {
            // ---- attention block ----
            for t in 0..n {
                rmsnorm(
                    &scratch.bx[t * d..(t + 1) * d],
                    &lw.norm_attn,
                    cfg.rms_eps,
                    &mut scratch.bnormed[t * d..(t + 1) * d],
                );
            }
            matmul(&scratch.bnormed, &lw.wq.data, &mut scratch.bq, n, d, qd);
            matmul(&scratch.bnormed, &lw.wk.data, &mut scratch.bk, n, d, kvd);
            matmul(&scratch.bnormed, &lw.wv.data, &mut scratch.bv, n, d, kvd);
            let backend = &mut state.backends[layer];
            backend.forward_batch(&scratch.bk, &scratch.bv, &scratch.bq, n, &mut scratch.battn);
            matmul(&scratch.battn, &lw.wo.data, &mut scratch.bproj, n, qd, d);
            for (xi, pi) in scratch.bx.iter_mut().zip(&scratch.bproj) {
                *xi += pi;
            }
            // ---- FFN block (SwiGLU) ----
            for t in 0..n {
                rmsnorm(
                    &scratch.bx[t * d..(t + 1) * d],
                    &lw.norm_ffn,
                    cfg.rms_eps,
                    &mut scratch.bnormed[t * d..(t + 1) * d],
                );
            }
            matmul(&scratch.bnormed, &lw.w_gate.data, &mut scratch.bgate, n, d, cfg.d_ff);
            matmul(&scratch.bnormed, &lw.w_up.data, &mut scratch.bup, n, d, cfg.d_ff);
            for (g, u) in scratch.bgate.iter_mut().zip(&scratch.bup) {
                *g = silu(*g) * u;
            }
            matmul(&scratch.bgate, &lw.w_down.data, &mut scratch.bffn, n, cfg.d_ff, d);
            for (xi, fi) in scratch.bx.iter_mut().zip(&scratch.bffn) {
                *xi += fi;
            }
        }
        state.pos += n;

        if !want_logits {
            return None;
        }
        // Final norm + tied LM head on the chunk's last row only.
        rmsnorm(&scratch.bx[(n - 1) * d..n * d], &w.norm_final, cfg.rms_eps, &mut scratch.normed);
        let mut logits = vec![0.0f32; cfg.vocab];
        for (t, l) in logits.iter_mut().enumerate() {
            *l = crate::tensor::ops::dot(w.embedding.row(t), &scratch.normed);
        }
        Some(logits)
    }

    /// Run a full prompt through the batched path, returning logits after
    /// the last token. Chunks of [`Model::PREFILL_CHUNK`].
    pub fn prefill(&self, state: &mut SequenceState, scratch: &mut Scratch, tokens: &[usize]) -> Vec<f32> {
        self.prefill_chunked(state, scratch, tokens, Self::PREFILL_CHUNK)
    }

    /// Chunked batched prefill with an explicit chunk size (1 recovers the
    /// token-at-a-time schedule, `tokens.len()` a single monolithic chunk).
    pub fn prefill_chunked(
        &self,
        state: &mut SequenceState,
        scratch: &mut Scratch,
        tokens: &[usize],
        chunk: usize,
    ) -> Vec<f32> {
        assert!(!tokens.is_empty());
        let chunk = chunk.max(1);
        let mut logits = None;
        let mut i = 0;
        while i < tokens.len() {
            let hi = (i + chunk).min(tokens.len());
            let last = hi == tokens.len();
            logits = self.forward_batch(state, scratch, &tokens[i..hi], last);
            i = hi;
        }
        state.end_prefill();
        scratch.end_prefill();
        logits.unwrap()
    }

    /// Greedy generation of `n` tokens after a prompt.
    pub fn generate_greedy(
        &self,
        state: &mut SequenceState,
        scratch: &mut Scratch,
        prompt: &[usize],
        n: usize,
    ) -> Vec<usize> {
        let mut logits = self.prefill(state, scratch, prompt);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let next = crate::tensor::ops::argmax(&logits);
            out.push(next);
            if state.pos >= self.cfg.max_seq {
                break;
            }
            logits = self.step(state, scratch, next, true).unwrap();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{AttnShape, FullAttention};

    fn full_factory(cfg: &ModelConfig) -> Box<BackendFactory> {
        let shape = cfg.attn_shape();
        Box::new(move |_layer| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>)
    }

    #[test]
    fn decode_produces_finite_logits() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 11)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        let logits = model.prefill(&mut state, &mut scratch, &[1, 2, 3, 4, 5]);
        assert_eq!(logits.len(), cfg.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(state.pos, 5);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 13)));
        let factory = full_factory(&cfg);
        let run = || {
            let mut state = SequenceState::new(&cfg, &factory);
            let mut scratch = Scratch::new(&cfg);
            model.generate_greedy(&mut state, &mut scratch, &[7, 8, 9], 5)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn batched_prefill_matches_per_token_decode() {
        // The batched path reassociates the arithmetic into blocked
        // matmuls, so equivalence with the sequential step() loop is
        // numerical (≤1e-4), for every chunking of the prompt.
        let cfg = ModelConfig::tiny_gqa(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 17)));
        let factory = full_factory(&cfg);
        let tokens = [3usize, 1, 4, 1, 5, 9, 2, 6];
        let mut s_ref = SequenceState::new(&cfg, &factory);
        let mut sc_ref = Scratch::new(&cfg);
        let mut reference = None;
        for (i, &t) in tokens.iter().enumerate() {
            reference = model.step(&mut s_ref, &mut sc_ref, t, i == tokens.len() - 1);
        }
        let reference = reference.unwrap();
        for chunk in [1, 2, 3, tokens.len()] {
            let mut s = SequenceState::new(&cfg, &factory);
            let mut sc = Scratch::new(&cfg);
            let logits = model.prefill_chunked(&mut s, &mut sc, &tokens, chunk);
            assert_eq!(s.pos, tokens.len());
            for (a, b) in logits.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "chunk {chunk}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn kv_bytes_grow_with_tokens() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 19)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        model.step(&mut state, &mut scratch, 1, false);
        let b1 = state.kv_bytes();
        model.step(&mut state, &mut scratch, 2, false);
        assert!(state.kv_bytes() > b1);
        let shape: AttnShape = cfg.attn_shape();
        assert_eq!(state.kv_bytes(), 2 * cfg.n_layers * 2 * shape.kv_dim() * 4);
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn rejects_bad_token() {
        let cfg = ModelConfig::tiny_mha(32);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 23)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        model.step(&mut state, &mut scratch, 99_999, false);
    }
}

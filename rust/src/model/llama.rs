//! LLaMA-style decoder forward pass over pluggable attention backends.
//!
//! Weights are shared (`Arc<Weights>`); per-sequence decode state (the KV
//! caches inside each layer's [`AttentionBackend`]) lives in
//! [`SequenceState`]. This split is what lets the coordinator batch many
//! sequences over one weight set, vLLM-style.
//!
//! Three forward paths share the weights:
//!
//! * [`Model::step`] — single-token, single-sequence decode: per-token
//!   vectors, `linear` accumulation loops, streaming attention. The
//!   reference semantics; also what `generate_greedy` drives.
//! * [`Model::forward_batch`] — multi-token prefill chunks for ONE
//!   sequence: (chunk, d_model) activation matrices driven through
//!   [`crate::tensor::ops::matmul`] against the weight matrices and through
//!   each backend's `forward_batch` (causal within the chunk).
//!   [`Model::prefill`] consumes the whole prompt in chunks of
//!   [`Model::PREFILL_CHUNK`].
//! * [`Model::decode_batch`] — one token for MANY sequences: stacks each
//!   running sequence's current token embedding into a (batch, d_model)
//!   matrix so every projection streams the shared weights per engine
//!   step (not once per sequence). Every decode operation is
//!   row-independent, so the rows are partitioned into contiguous blocks
//!   across the scratch's [`Workers`] handle (persistent-pool dispatch —
//!   no thread spawn per step); each worker drives stacked matmuls, its
//!   sequences' private per-layer `append`/`attend`, and the batched
//!   tied-embedding LM head for its block, with the leftover worker
//!   budget granted to its sequences' intra-attend fan-out as nested
//!   sub-shares (total live workers never exceed the handle width).
//!   Per-row arithmetic is ordered identically to [`Model::step`], so
//!   the batch dimension is numerically invisible.

use super::config::ModelConfig;
use super::weights::Weights;
use crate::attention::{AttentionBackend, FootprintModel, PrefixSnapshot};
use crate::tensor::ops::{gather_rows, lm_head_batch, matmul, rmsnorm, silu};
use crate::util::threadpool::Workers;
use std::sync::Arc;

/// Factory producing one attention backend per layer.
pub type BackendFactory = dyn Fn(usize) -> Box<dyn AttentionBackend + Send> + Send + Sync;

/// Predicted per-sequence cache footprint across all layers — one
/// [`FootprintModel`] per layer (layers legitimately differ: dense-layer
/// skipping, per-layer compression ratios à la LoRC/Palu). Built from a
/// factory *without running any tokens*: each layer backend is constructed
/// once, empty, and asked for its model. This is what the serving engine
/// prices admission with; the live counterpart is
/// [`SequenceState::kv_bytes`].
pub struct SequenceFootprint {
    layers: Vec<FootprintModel>,
}

impl SequenceFootprint {
    /// Derive the footprint of sequences this factory would produce.
    pub fn of(cfg: &ModelConfig, factory: &BackendFactory) -> SequenceFootprint {
        SequenceFootprint { layers: (0..cfg.n_layers).map(|l| factory(l).footprint()).collect() }
    }

    /// Assemble a footprint from explicit per-layer models (router setup
    /// without constructing backends, tests).
    pub fn from_layers(layers: Vec<FootprintModel>) -> SequenceFootprint {
        SequenceFootprint { layers }
    }

    /// Projected resident KV bytes of one sequence at `tokens` total
    /// length (prompt + generated).
    pub fn bytes_at(&self, tokens: usize) -> usize {
        self.layers.iter().map(|m| m.bytes_at(tokens)).sum()
    }

    /// Per-layer models (for reports / tests).
    pub fn layers(&self) -> &[FootprintModel] {
        &self.layers
    }
}

/// Immutable multi-layer prefix capture of a [`SequenceState`] — one
/// [`PrefixSnapshot`] per layer, all frozen at the same token count.
/// Cloning is cheap (per-layer `Arc` bumps); the engine's prefix cache
/// holds these and hands clones to adopting sequences.
#[derive(Clone)]
pub struct SequenceSnapshot {
    /// Prompt tokens the snapshot covers (every layer agrees).
    pub n_tokens: usize,
    layers: Vec<PrefixSnapshot>,
}

impl SequenceSnapshot {
    /// Refcount-shared resident bytes across all layers — what adopters
    /// hold by reference instead of re-materializing.
    pub fn shared_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.shared_bytes).sum()
    }
}

/// Per-sequence decode state: one KV backend per layer + position counter.
pub struct SequenceState {
    pub backends: Vec<Box<dyn AttentionBackend + Send>>,
    pub pos: usize,
}

impl SequenceState {
    pub fn new(cfg: &ModelConfig, factory: &BackendFactory) -> SequenceState {
        SequenceState { backends: (0..cfg.n_layers).map(|l| factory(l)).collect(), pos: 0 }
    }

    /// Total resident KV bytes across layers.
    pub fn kv_bytes(&self) -> usize {
        self.backends.iter().map(|b| b.kv_bytes()).sum()
    }

    /// Prefill finished: let every layer backend drop chunk-sized scratch
    /// before the (long) decode phase.
    pub fn end_prefill(&mut self) {
        for b in &mut self.backends {
            b.end_prefill();
        }
    }

    /// Propagate a worker-pool sub-handle to every layer backend
    /// ([`AttentionBackend::set_workers`]): when the decode batch is
    /// smaller than the worker pool, the leftover lanes parallelize
    /// *inside* each sequence's attend (per-KV-head panels, split-KV
    /// segments, token-block score scans) instead of idling — batch-1
    /// long-context decode finally uses the fan-out. Purely a
    /// scheduling knob: backends guarantee bit-identical output for
    /// every handle width and pool size.
    pub fn set_attend_workers(&mut self, workers: &Workers) {
        for b in &mut self.backends {
            b.set_workers(workers);
        }
    }

    /// Freeze the first `n_tokens` of every layer backend as an immutable
    /// refcounted snapshot ([`AttentionBackend::fork_prefix`]). All-or-
    /// nothing: `None` if any layer declines (e.g. `n_tokens` is not the
    /// backend's full current length, or live sparse-prefill state).
    pub fn fork_prefix(&self, n_tokens: usize) -> Option<SequenceSnapshot> {
        let mut layers = Vec::with_capacity(self.backends.len());
        for b in &self.backends {
            layers.push(b.fork_prefix(n_tokens)?);
        }
        Some(SequenceSnapshot { n_tokens, layers })
    }

    /// Adopt a snapshot into a fresh state (pos 0, empty backends): every
    /// layer takes its panel by reference, and `pos` jumps to the
    /// snapshot's length. Returns false if the state has already run
    /// tokens, the layer counts disagree, or any backend refuses — on
    /// false the state may be partially adopted and must be discarded,
    /// not cold-prefilled in place.
    pub fn adopt_prefix(&mut self, snap: &SequenceSnapshot) -> bool {
        if self.pos != 0 || snap.layers.len() != self.backends.len() {
            return false;
        }
        for (b, l) in self.backends.iter_mut().zip(&snap.layers) {
            if !b.adopt_prefix(l) {
                return false;
            }
        }
        self.pos = snap.n_tokens;
        true
    }

    /// Resident bytes held by reference to adopted shared prefixes,
    /// summed over layers — [`SequenceState::kv_bytes`] includes them
    /// (footprint models stay reuse-unaware), so pool accounting subtracts
    /// this to charge shared pages once across all adopters.
    pub fn shared_prefix_bytes(&self) -> usize {
        self.backends.iter().map(|b| b.shared_prefix_bytes()).sum()
    }

    /// Total cache traffic across layers.
    pub fn traffic(&self) -> crate::attention::Traffic {
        let mut t = crate::attention::Traffic::default();
        for b in &self.backends {
            let bt = b.traffic();
            t.read += bt.read;
            t.written += bt.written;
        }
        t
    }
}

/// The shared model: config + weights. Stateless across sequences.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: Arc<Weights>,
}

/// Scratch buffers for one forward step (reused across steps).
///
/// The `b*` buffers are the batched-prefill activation matrices ((chunk, ·)
/// row-major); they start empty and are grown to the chunk size on first
/// use, so decode-only sequences pay nothing for them.
pub struct Scratch {
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    ffn: Vec<f32>,
    // ---- batched prefill ((chunk, ·) matrices) ----
    bx: Vec<f32>,
    bnormed: Vec<f32>,
    bq: Vec<f32>,
    bk: Vec<f32>,
    bv: Vec<f32>,
    battn: Vec<f32>,
    bproj: Vec<f32>,
    bgate: Vec<f32>,
    bup: Vec<f32>,
    bffn: Vec<f32>,
}

impl Scratch {
    pub fn new(cfg: &ModelConfig) -> Scratch {
        Scratch {
            x: vec![0.0; cfg.d_model],
            normed: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * cfg.head_dim],
            k: vec![0.0; cfg.kv_dim()],
            v: vec![0.0; cfg.kv_dim()],
            attn_out: vec![0.0; cfg.n_heads * cfg.head_dim],
            proj: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.d_ff],
            up: vec![0.0; cfg.d_ff],
            ffn: vec![0.0; cfg.d_model],
            bx: Vec::new(),
            bnormed: Vec::new(),
            bq: Vec::new(),
            bk: Vec::new(),
            bv: Vec::new(),
            battn: Vec::new(),
            bproj: Vec::new(),
            bgate: Vec::new(),
            bup: Vec::new(),
            bffn: Vec::new(),
        }
    }

    /// Release the batched-prefill activation matrices — decode touches
    /// only the single-token buffers, and the `b*` set is chunk-sized
    /// (bgate/bup alone are 2·chunk·d_ff floats), so holding it through a
    /// long decode phase would inflate every running sequence's footprint.
    pub fn end_prefill(&mut self) {
        for buf in [
            &mut self.bx,
            &mut self.bnormed,
            &mut self.bq,
            &mut self.bk,
            &mut self.bv,
            &mut self.battn,
            &mut self.bproj,
            &mut self.bgate,
            &mut self.bup,
            &mut self.bffn,
        ] {
            *buf = Vec::new();
        }
    }

    /// Size the batched buffers for an `n`-token chunk (exact lengths —
    /// the matmul kernels assert full-slice shapes; callers slice to the
    /// active size).
    fn ensure_batch(&mut self, cfg: &ModelConfig, n: usize) {
        let d = cfg.d_model;
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_dim();
        self.bx.resize(n * d, 0.0);
        self.bnormed.resize(n * d, 0.0);
        self.bq.resize(n * qd, 0.0);
        self.bk.resize(n * kvd, 0.0);
        self.bv.resize(n * kvd, 0.0);
        self.battn.resize(n * qd, 0.0);
        self.bproj.resize(n * d, 0.0);
        self.bgate.resize(n * cfg.d_ff, 0.0);
        self.bup.resize(n * cfg.d_ff, 0.0);
        self.bffn.resize(n * d, 0.0);
    }
}

/// Scratch for [`Model::decode_batch`]: (batch, ·) row-major activation
/// matrices, owned by the *caller* (one per engine, sized to its
/// `max_batch`) rather than per sequence — cross-sequence decode is a
/// property of the scheduler, not of any one sequence. Buffers grow to the
/// largest batch seen and are retained across steps, so the steady-state
/// decode loop is allocation-free except for the returned logits.
pub struct BatchScratch {
    /// Worker handle the per-step decode fan-out dispatches on. Usually a
    /// clone of the engine's persistent-pool handle so steps reuse the
    /// same parked workers instead of spawning; width caps the fan-out.
    workers: Workers,
    bx: Vec<f32>,
    bnormed: Vec<f32>,
    bq: Vec<f32>,
    bk: Vec<f32>,
    bv: Vec<f32>,
    battn: Vec<f32>,
    bproj: Vec<f32>,
    bgate: Vec<f32>,
    bup: Vec<f32>,
    bffn: Vec<f32>,
    blogits: Vec<f32>,
}

impl BatchScratch {
    /// Empty scratch; buffers grow on first [`Model::decode_batch`] call.
    /// `threads` caps the per-step worker fan-out (0 = one per CPU,
    /// `SALS_THREADS` overrides; always further capped by the batch
    /// size); widths above 1 mint a private persistent pool — callers
    /// that already own one should use [`BatchScratch::with_workers`].
    pub fn new(threads: usize) -> BatchScratch {
        BatchScratch::with_workers(Workers::auto(threads))
    }

    /// Empty scratch dispatching on an existing worker handle (e.g. the
    /// engine's pool) instead of minting its own.
    pub fn with_workers(workers: Workers) -> BatchScratch {
        BatchScratch {
            workers,
            bx: Vec::new(),
            bnormed: Vec::new(),
            bq: Vec::new(),
            bk: Vec::new(),
            bv: Vec::new(),
            battn: Vec::new(),
            bproj: Vec::new(),
            bgate: Vec::new(),
            bup: Vec::new(),
            bffn: Vec::new(),
            blogits: Vec::new(),
        }
    }

    /// Pre-sized scratch for decode batches up to `max_batch` sequences:
    /// reserves the full-batch capacity up front so later [`Self::ensure`]
    /// calls never reallocate (Vec capacity is retained across the exact
    /// resizes as the engine's decode set grows and shrinks).
    pub fn sized(cfg: &ModelConfig, max_batch: usize, threads: usize) -> BatchScratch {
        BatchScratch::sized_with(cfg, max_batch, Workers::auto(threads))
    }

    /// [`BatchScratch::sized`] on an existing worker handle.
    pub fn sized_with(cfg: &ModelConfig, max_batch: usize, workers: Workers) -> BatchScratch {
        let mut s = BatchScratch::with_workers(workers);
        s.ensure(cfg, max_batch.max(1));
        s
    }

    /// Size every buffer for exactly a `b`-sequence batch — the same
    /// exact-length policy as [`Scratch::ensure_batch`] (the matmul
    /// kernels and residual zips assert full-slice shapes, so exactness is
    /// load-bearing, not cosmetic). Shrinking keeps capacity, so batches
    /// that vary step to step stay allocation-free.
    fn ensure(&mut self, cfg: &ModelConfig, b: usize) {
        let d = cfg.d_model;
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_dim();
        self.bx.resize(b * d, 0.0);
        self.bnormed.resize(b * d, 0.0);
        self.bq.resize(b * qd, 0.0);
        self.bk.resize(b * kvd, 0.0);
        self.bv.resize(b * kvd, 0.0);
        self.battn.resize(b * qd, 0.0);
        self.bproj.resize(b * d, 0.0);
        self.bgate.resize(b * cfg.d_ff, 0.0);
        self.bup.resize(b * cfg.d_ff, 0.0);
        self.bffn.resize(b * d, 0.0);
        self.blogits.resize(b * cfg.vocab, 0.0);
    }
}

/// Mutable views over a contiguous block of [`BatchScratch`]'s rows — the
/// unit of work one decode worker owns. Splitting the batch this way is
/// safe because every decode operation is row-independent.
struct DecodeRows<'a> {
    bx: &'a mut [f32],
    bnormed: &'a mut [f32],
    bq: &'a mut [f32],
    bk: &'a mut [f32],
    bv: &'a mut [f32],
    battn: &'a mut [f32],
    bproj: &'a mut [f32],
    bgate: &'a mut [f32],
    bup: &'a mut [f32],
    bffn: &'a mut [f32],
    blogits: &'a mut [f32],
}

impl<'a> DecodeRows<'a> {
    /// Split off the first `nb` rows of every matrix; returns (head, rest).
    fn split_rows(self, nb: usize, cfg: &ModelConfig) -> (DecodeRows<'a>, DecodeRows<'a>) {
        let d = cfg.d_model;
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_dim();
        let (bx, bx_r) = self.bx.split_at_mut(nb * d);
        let (bnormed, bnormed_r) = self.bnormed.split_at_mut(nb * d);
        let (bq, bq_r) = self.bq.split_at_mut(nb * qd);
        let (bk, bk_r) = self.bk.split_at_mut(nb * kvd);
        let (bv, bv_r) = self.bv.split_at_mut(nb * kvd);
        let (battn, battn_r) = self.battn.split_at_mut(nb * qd);
        let (bproj, bproj_r) = self.bproj.split_at_mut(nb * d);
        let (bgate, bgate_r) = self.bgate.split_at_mut(nb * cfg.d_ff);
        let (bup, bup_r) = self.bup.split_at_mut(nb * cfg.d_ff);
        let (bffn, bffn_r) = self.bffn.split_at_mut(nb * d);
        let (blogits, blogits_r) = self.blogits.split_at_mut(nb * cfg.vocab);
        (
            DecodeRows { bx, bnormed, bq, bk, bv, battn, bproj, bgate, bup, bffn, blogits },
            DecodeRows {
                bx: bx_r,
                bnormed: bnormed_r,
                bq: bq_r,
                bk: bk_r,
                bv: bv_r,
                battn: battn_r,
                bproj: bproj_r,
                bgate: bgate_r,
                bup: bup_r,
                bffn: bffn_r,
                blogits: blogits_r,
            },
        )
    }
}

/// One decode worker's slice of the batch: its sequences, their tokens,
/// and its block of the scratch matrices. `rows` is an `Option` only so
/// the fan-out closure can move the views into [`Model::decode_rows`]
/// (which consumes them) through a `&mut` borrow.
struct DecodeUnit<'s, 'q, 'v> {
    states: &'s mut [&'q mut SequenceState],
    tokens: &'s [usize],
    rows: Option<DecodeRows<'v>>,
}

/// y = x @ W  for a (d_in, d_out) weight; `out` is overwritten.
///
/// One m = 1 [`crate::tensor::ops::matmul`] row: the batch-of-1 decode
/// hot path rides the shared SIMD-dispatched row kernels (row_set/axpy,
/// with the zeroing folded into the first pass) instead of keeping a
/// private scalar loop.
fn linear(x: &[f32], w: &crate::tensor::Mat, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.rows);
    debug_assert_eq!(out.len(), w.cols);
    matmul(x, &w.data, out, 1, w.rows, w.cols);
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Arc<Weights>) -> Model {
        cfg.validate().expect("invalid model config");
        Model { cfg, weights }
    }

    /// One decode step: feed `token`, advance `state`, return logits.
    ///
    /// `process_only`: during prefill we still must append KV and run the
    /// layers (the residual stream feeds later keys), but logits can be
    /// skipped; pass `false` to skip the LM head.
    pub fn step(&self, state: &mut SequenceState, scratch: &mut Scratch, token: usize, want_logits: bool) -> Option<Vec<f32>> {
        let cfg = &self.cfg;
        let w = &self.weights;
        assert!(token < cfg.vocab, "token {token} out of vocab");
        assert!(state.pos < cfg.max_seq, "sequence exceeds max_seq");

        // Embed.
        scratch.x.copy_from_slice(w.embedding.row(token));

        for (layer, lw) in w.layers.iter().enumerate() {
            // ---- attention block ----
            rmsnorm(&scratch.x, &lw.norm_attn, cfg.rms_eps, &mut scratch.normed);
            linear(&scratch.normed, &lw.wq, &mut scratch.q);
            linear(&scratch.normed, &lw.wk, &mut scratch.k);
            linear(&scratch.normed, &lw.wv, &mut scratch.v);
            let backend = &mut state.backends[layer];
            backend.append(&scratch.k, &scratch.v);
            backend.attend(&scratch.q, &mut scratch.attn_out);
            linear(&scratch.attn_out, &lw.wo, &mut scratch.proj);
            for (xi, pi) in scratch.x.iter_mut().zip(&scratch.proj) {
                *xi += pi;
            }
            // ---- FFN block (SwiGLU) ----
            rmsnorm(&scratch.x, &lw.norm_ffn, cfg.rms_eps, &mut scratch.normed);
            linear(&scratch.normed, &lw.w_gate, &mut scratch.gate);
            linear(&scratch.normed, &lw.w_up, &mut scratch.up);
            for (g, u) in scratch.gate.iter_mut().zip(&scratch.up) {
                *g = silu(*g) * u;
            }
            linear(&scratch.gate, &lw.w_down, &mut scratch.ffn);
            for (xi, fi) in scratch.x.iter_mut().zip(&scratch.ffn) {
                *xi += fi;
            }
        }
        state.pos += 1;

        if !want_logits {
            return None;
        }
        // Final norm + tied LM head (a batch-of-1 `lm_head_batch`).
        rmsnorm(&scratch.x, &w.norm_final, cfg.rms_eps, &mut scratch.normed);
        let mut logits = vec![0.0f32; cfg.vocab];
        lm_head_batch(&scratch.normed, &w.embedding.data, &mut logits, 1, cfg.d_model, cfg.vocab);
        Some(logits)
    }

    /// Default prefill chunk size (tokens per [`Model::forward_batch`] call)
    /// used by [`Model::prefill`]. Large enough that the per-chunk matmuls
    /// amortize, small enough that activation scratch stays modest.
    pub const PREFILL_CHUNK: usize = 128;

    /// Multi-token chunk forward: feed `tokens`, advance `state` by
    /// `tokens.len()` positions, and return the logits after the last
    /// token if `want_logits`.
    ///
    /// The chunk's activations travel as (n, d) row-major matrices —
    /// rmsnorm per row, QKV/output/FFN projections as single matmuls
    /// against the shared weights, and attention through each layer
    /// backend's `forward_batch` (causal within the chunk). Semantically
    /// equivalent to `n` calls of [`Model::step`]; the arithmetic is
    /// reassociated into blocked kernels, so logits agree to ~1e-5, not
    /// bit-exactly.
    pub fn forward_batch(
        &self,
        state: &mut SequenceState,
        scratch: &mut Scratch,
        tokens: &[usize],
        want_logits: bool,
    ) -> Option<Vec<f32>> {
        let cfg = &self.cfg;
        let w = &self.weights;
        let n = tokens.len();
        assert!(n > 0, "forward_batch of empty chunk");
        assert!(state.pos + n <= cfg.max_seq, "sequence exceeds max_seq");
        let d = cfg.d_model;
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_dim();
        scratch.ensure_batch(cfg, n);

        // Embed the chunk.
        for (t, &tok) in tokens.iter().enumerate() {
            assert!(tok < cfg.vocab, "token {tok} out of vocab");
            scratch.bx[t * d..(t + 1) * d].copy_from_slice(w.embedding.row(tok));
        }

        for (layer, lw) in w.layers.iter().enumerate() {
            // ---- attention block ----
            for t in 0..n {
                rmsnorm(
                    &scratch.bx[t * d..(t + 1) * d],
                    &lw.norm_attn,
                    cfg.rms_eps,
                    &mut scratch.bnormed[t * d..(t + 1) * d],
                );
            }
            matmul(&scratch.bnormed, &lw.wq.data, &mut scratch.bq, n, d, qd);
            matmul(&scratch.bnormed, &lw.wk.data, &mut scratch.bk, n, d, kvd);
            matmul(&scratch.bnormed, &lw.wv.data, &mut scratch.bv, n, d, kvd);
            let backend = &mut state.backends[layer];
            backend.forward_batch(&scratch.bk, &scratch.bv, &scratch.bq, n, &mut scratch.battn);
            matmul(&scratch.battn, &lw.wo.data, &mut scratch.bproj, n, qd, d);
            for (xi, pi) in scratch.bx.iter_mut().zip(&scratch.bproj) {
                *xi += pi;
            }
            // ---- FFN block (SwiGLU) ----
            for t in 0..n {
                rmsnorm(
                    &scratch.bx[t * d..(t + 1) * d],
                    &lw.norm_ffn,
                    cfg.rms_eps,
                    &mut scratch.bnormed[t * d..(t + 1) * d],
                );
            }
            matmul(&scratch.bnormed, &lw.w_gate.data, &mut scratch.bgate, n, d, cfg.d_ff);
            matmul(&scratch.bnormed, &lw.w_up.data, &mut scratch.bup, n, d, cfg.d_ff);
            for (g, u) in scratch.bgate.iter_mut().zip(&scratch.bup) {
                *g = silu(*g) * u;
            }
            matmul(&scratch.bgate, &lw.w_down.data, &mut scratch.bffn, n, cfg.d_ff, d);
            for (xi, fi) in scratch.bx.iter_mut().zip(&scratch.bffn) {
                *xi += fi;
            }
        }
        state.pos += n;

        if !want_logits {
            return None;
        }
        // Final norm + tied LM head on the chunk's last row only.
        rmsnorm(&scratch.bx[(n - 1) * d..n * d], &w.norm_final, cfg.rms_eps, &mut scratch.normed);
        let mut logits = vec![0.0f32; cfg.vocab];
        lm_head_batch(&scratch.normed, &w.embedding.data, &mut logits, 1, d, cfg.vocab);
        Some(logits)
    }

    /// Cross-sequence batched decode: one token for each of `states.len()`
    /// independent sequences in a single stacked forward pass.
    ///
    /// `tokens[i]` is fed to `states[i]`; returns one logits vector per
    /// sequence, in order. The batch travels as (batch, ·) row-major
    /// activation matrices: every rmsnorm is per-row, every projection
    /// (QKV, output, FFN, LM head) is a stacked matmul against the shared
    /// weights — so each weight matrix streams from memory once per engine
    /// step for a whole block of sequences instead of once per sequence,
    /// which is where continuous batching wins on real hardware.
    ///
    /// Parallelism: every decode operation is row-independent (matmul
    /// rows, rmsnorm rows, residual rows, and attention, which is
    /// per-sequence private cache state), so the batch's rows are
    /// partitioned into contiguous blocks across `scratch.workers` —
    /// persistent-pool dispatch, no thread spawned per step — and each
    /// worker drives the full forward for its block, stacked matmuls
    /// included. When the batch is smaller than the handle width, the
    /// spare lanes are granted to the blocks as nested sub-handles so
    /// each sequence's intra-attend fan-out (score scans, split-KV
    /// segments) soaks them up; the shares are carved from one budget,
    /// so live workers never exceed the handle width. Workers read the
    /// shared weights concurrently and advance in lockstep-ish layer
    /// order, so the weight stream is still amortized across the batch.
    ///
    /// Row `i` of every batched operation accumulates in exactly the
    /// order [`Model::step`] would (and row partitioning cannot change
    /// per-row arithmetic), so `decode_batch` over k sequences is
    /// numerically indistinguishable from k independent `step` calls —
    /// batching is a scheduling choice, not a semantic one.
    pub fn decode_batch(
        &self,
        states: &mut [&mut SequenceState],
        tokens: &[usize],
        scratch: &mut BatchScratch,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let b = states.len();
        assert!(b > 0, "decode_batch of empty sequence set");
        assert_eq!(tokens.len(), b, "one token per sequence");
        for (i, (s, &t)) in states.iter().zip(tokens).enumerate() {
            assert!(t < cfg.vocab, "token {t} out of vocab");
            assert!(s.pos < cfg.max_seq, "sequence {i} exceeds max_seq");
        }
        scratch.ensure(cfg, b);
        let workers = scratch.workers.clone();
        let width = workers.width().min(b);

        let all = DecodeRows {
            bx: &mut scratch.bx,
            bnormed: &mut scratch.bnormed,
            bq: &mut scratch.bq,
            bk: &mut scratch.bk,
            bv: &mut scratch.bv,
            battn: &mut scratch.battn,
            bproj: &mut scratch.bproj,
            bgate: &mut scratch.bgate,
            bup: &mut scratch.bup,
            bffn: &mut scratch.bffn,
            blogits: &mut scratch.blogits,
        };
        if width <= 1 {
            // A solo block still inherits the whole handle: its
            // sequences' intra-attend fan-out is the only consumer, so
            // batch-1 long-context decode uses the full pool.
            for s in states.iter_mut() {
                s.set_attend_workers(&workers);
            }
            self.decode_rows(states, tokens, all);
        } else {
            // Carve (states, tokens, rows) into per-worker contiguous
            // blocks, then let the handle both run the blocks and grant
            // each one its disjoint share of the leftover lanes.
            let chunk = b.div_ceil(width);
            let mut rem_states: &mut [&mut SequenceState] = states;
            let mut rem_tokens: &[usize] = tokens;
            let mut rem = all;
            let mut units = Vec::with_capacity(width);
            while !rem_states.is_empty() {
                let nb = chunk.min(rem_states.len());
                let (st, rs) = std::mem::take(&mut rem_states).split_at_mut(nb);
                rem_states = rs;
                let (tk, rt) = rem_tokens.split_at(nb);
                rem_tokens = rt;
                let (views, rest) = rem.split_rows(nb, cfg);
                rem = rest;
                units.push(DecodeUnit { states: st, tokens: tk, rows: Some(views) });
            }
            workers.nested_for_each_mut(&mut units, |_, unit, sub| {
                for s in unit.states.iter_mut() {
                    s.set_attend_workers(sub);
                }
                self.decode_rows(unit.states, unit.tokens, unit.rows.take().unwrap());
            });
        }
        scratch.blogits.chunks(cfg.vocab).map(|r| r.to_vec()).collect()
    }

    /// The full decode forward for one contiguous block of batch rows, on
    /// the calling thread. [`Model::decode_batch`] partitions rows across
    /// workers and each runs this serially; `v`'s matrices hold exactly
    /// `states.len()` rows.
    fn decode_rows(&self, states: &mut [&mut SequenceState], tokens: &[usize], v: DecodeRows<'_>) {
        let cfg = &self.cfg;
        let w = &self.weights;
        let nb = states.len();
        let d = cfg.d_model;
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.kv_dim();
        let DecodeRows { bx, bnormed, bq, bk, bv, battn, bproj, bgate, bup, bffn, blogits } = v;

        // Embed: stack each sequence's current token into one (nb, d) matrix.
        gather_rows(&w.embedding.data, d, tokens, bx);

        for (layer, lw) in w.layers.iter().enumerate() {
            // ---- attention block ----
            for t in 0..nb {
                rmsnorm(
                    &bx[t * d..(t + 1) * d],
                    &lw.norm_attn,
                    cfg.rms_eps,
                    &mut bnormed[t * d..(t + 1) * d],
                );
            }
            matmul(bnormed, &lw.wq.data, bq, nb, d, qd);
            matmul(bnormed, &lw.wk.data, bk, nb, d, kvd);
            matmul(bnormed, &lw.wv.data, bv, nb, d, kvd);
            // Per-sequence append/attend against private caches; attention
            // outputs land straight in this block's rows of the batch
            // matrix, so the "gather" back is in-place.
            for (i, (s, orow)) in states.iter_mut().zip(battn.chunks_mut(qd)).enumerate() {
                let backend = &mut s.backends[layer];
                backend.append(&bk[i * kvd..(i + 1) * kvd], &bv[i * kvd..(i + 1) * kvd]);
                backend.attend(&bq[i * qd..(i + 1) * qd], orow);
            }
            matmul(battn, &lw.wo.data, bproj, nb, qd, d);
            for (xi, pi) in bx.iter_mut().zip(bproj.iter()) {
                *xi += pi;
            }
            // ---- FFN block (SwiGLU) ----
            for t in 0..nb {
                rmsnorm(
                    &bx[t * d..(t + 1) * d],
                    &lw.norm_ffn,
                    cfg.rms_eps,
                    &mut bnormed[t * d..(t + 1) * d],
                );
            }
            matmul(bnormed, &lw.w_gate.data, bgate, nb, d, cfg.d_ff);
            matmul(bnormed, &lw.w_up.data, bup, nb, d, cfg.d_ff);
            for (g, u) in bgate.iter_mut().zip(bup.iter()) {
                *g = silu(*g) * u;
            }
            matmul(bgate, &lw.w_down.data, bffn, nb, cfg.d_ff, d);
            for (xi, fi) in bx.iter_mut().zip(bffn.iter()) {
                *xi += fi;
            }
        }
        for s in states.iter_mut() {
            s.pos += 1;
        }

        // Final norm + one stacked tied-embedding LM head for the block.
        for t in 0..nb {
            rmsnorm(
                &bx[t * d..(t + 1) * d],
                &w.norm_final,
                cfg.rms_eps,
                &mut bnormed[t * d..(t + 1) * d],
            );
        }
        lm_head_batch(bnormed, &w.embedding.data, blogits, nb, d, cfg.vocab);
    }

    /// Run a full prompt through the batched path, returning logits after
    /// the last token. Chunks of [`Model::PREFILL_CHUNK`].
    pub fn prefill(&self, state: &mut SequenceState, scratch: &mut Scratch, tokens: &[usize]) -> Vec<f32> {
        self.prefill_chunked(state, scratch, tokens, Self::PREFILL_CHUNK)
    }

    /// Chunked batched prefill with an explicit chunk size (1 recovers the
    /// token-at-a-time schedule, `tokens.len()` a single monolithic chunk).
    pub fn prefill_chunked(
        &self,
        state: &mut SequenceState,
        scratch: &mut Scratch,
        tokens: &[usize],
        chunk: usize,
    ) -> Vec<f32> {
        assert!(!tokens.is_empty());
        let chunk = chunk.max(1);
        let mut logits = None;
        let mut i = 0;
        while i < tokens.len() {
            let hi = (i + chunk).min(tokens.len());
            let last = hi == tokens.len();
            logits = self.forward_batch(state, scratch, &tokens[i..hi], last);
            i = hi;
        }
        state.end_prefill();
        scratch.end_prefill();
        logits.unwrap()
    }

    /// Greedy generation of `n` tokens after a prompt.
    pub fn generate_greedy(
        &self,
        state: &mut SequenceState,
        scratch: &mut Scratch,
        prompt: &[usize],
        n: usize,
    ) -> Vec<usize> {
        let mut logits = self.prefill(state, scratch, prompt);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let next = crate::tensor::ops::argmax(&logits);
            out.push(next);
            if state.pos >= self.cfg.max_seq {
                break;
            }
            logits = self.step(state, scratch, next, true).unwrap();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{AttnShape, FullAttention};

    fn full_factory(cfg: &ModelConfig) -> Box<BackendFactory> {
        let shape = cfg.attn_shape();
        Box::new(move |_layer| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>)
    }

    #[test]
    fn decode_produces_finite_logits() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 11)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        let logits = model.prefill(&mut state, &mut scratch, &[1, 2, 3, 4, 5]);
        assert_eq!(logits.len(), cfg.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(state.pos, 5);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 13)));
        let factory = full_factory(&cfg);
        let run = || {
            let mut state = SequenceState::new(&cfg, &factory);
            let mut scratch = Scratch::new(&cfg);
            model.generate_greedy(&mut state, &mut scratch, &[7, 8, 9], 5)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn batched_prefill_matches_per_token_decode() {
        // The batched path reassociates the arithmetic into blocked
        // matmuls, so equivalence with the sequential step() loop is
        // numerical (≤1e-4), for every chunking of the prompt.
        let cfg = ModelConfig::tiny_gqa(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 17)));
        let factory = full_factory(&cfg);
        let tokens = [3usize, 1, 4, 1, 5, 9, 2, 6];
        let mut s_ref = SequenceState::new(&cfg, &factory);
        let mut sc_ref = Scratch::new(&cfg);
        let mut reference = None;
        for (i, &t) in tokens.iter().enumerate() {
            reference = model.step(&mut s_ref, &mut sc_ref, t, i == tokens.len() - 1);
        }
        let reference = reference.unwrap();
        for chunk in [1, 2, 3, tokens.len()] {
            let mut s = SequenceState::new(&cfg, &factory);
            let mut sc = Scratch::new(&cfg);
            let logits = model.prefill_chunked(&mut s, &mut sc, &tokens, chunk);
            assert_eq!(s.pos, tokens.len());
            for (a, b) in logits.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "chunk {chunk}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn decode_batch_matches_independent_steps() {
        // k sequences with different prompts: one decode_batch call must
        // reproduce k independent step() calls. Per-row arithmetic order is
        // identical, so the tolerance is tight.
        let cfg = ModelConfig::tiny_gqa(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 43)));
        let factory = full_factory(&cfg);
        let prompts: Vec<Vec<usize>> = vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9], vec![10]];
        let tokens = [11usize, 12, 13, 14];

        // Reference: per-sequence step() decode.
        let mut reference = Vec::new();
        for (p, &t) in prompts.iter().zip(&tokens) {
            let mut state = SequenceState::new(&cfg, &factory);
            let mut sc = Scratch::new(&cfg);
            model.prefill(&mut state, &mut sc, p);
            reference.push((model.step(&mut state, &mut sc, t, true).unwrap(), state));
        }

        // Batched: same prompts, one stacked decode.
        let mut states: Vec<SequenceState> = prompts
            .iter()
            .map(|p| {
                let mut s = SequenceState::new(&cfg, &factory);
                let mut sc = Scratch::new(&cfg);
                model.prefill(&mut s, &mut sc, p);
                s
            })
            .collect();
        let mut scratch = BatchScratch::new(2);
        let mut refs: Vec<&mut SequenceState> = states.iter_mut().collect();
        let logits = model.decode_batch(&mut refs, &tokens, &mut scratch);
        assert_eq!(logits.len(), prompts.len());
        for (i, (l, (ref_l, ref_s))) in logits.iter().zip(&reference).enumerate() {
            assert_eq!(states[i].pos, ref_s.pos, "seq {i}: position");
            assert_eq!(states[i].kv_bytes(), ref_s.kv_bytes(), "seq {i}: cache size");
            for (a, b) in l.iter().zip(ref_l) {
                assert!((a - b).abs() < 1e-5, "seq {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn decode_batch_scratch_reuse_and_growth() {
        // A warm BatchScratch sized by a larger batch must serve a smaller
        // one (engine batches shrink as sequences finish), and repeated
        // steps through the same scratch must stay consistent with step().
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 47)));
        let factory = full_factory(&cfg);
        let mut scratch = BatchScratch::sized(&cfg, 3, 1);

        let mut a = SequenceState::new(&cfg, &factory);
        let mut b = SequenceState::new(&cfg, &factory);
        let mut c = SequenceState::new(&cfg, &factory);
        for (s, tok) in [(&mut a, 1usize), (&mut b, 2), (&mut c, 3)] {
            let mut sc = Scratch::new(&cfg);
            model.prefill(s, &mut sc, &[tok, tok + 10]);
        }
        // Step all three, then only two (c "finished").
        let mut refs: Vec<&mut SequenceState> = vec![&mut a, &mut b, &mut c];
        let l3 = model.decode_batch(&mut refs, &[20, 21, 22], &mut scratch);
        let mut refs: Vec<&mut SequenceState> = vec![&mut a, &mut b];
        let l2 = model.decode_batch(&mut refs, &[23, 24], &mut scratch);
        assert_eq!(l3.len(), 3);
        assert_eq!(l2.len(), 2);
        assert_eq!(a.pos, 4);
        assert_eq!(c.pos, 3);

        // Reference sequence driven by step() alone.
        let mut r = SequenceState::new(&cfg, &factory);
        let mut sc = Scratch::new(&cfg);
        model.prefill(&mut r, &mut sc, &[1, 11]);
        model.step(&mut r, &mut sc, 20, false);
        let ref_l = model.step(&mut r, &mut sc, 23, true).unwrap();
        for (x, y) in l2[0].iter().zip(&ref_l) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn decode_batch_is_bit_invariant_across_pool_sizes() {
        // The row partition and the nested attend sub-shares are
        // scheduling only: the same batch through serial, narrow-pooled,
        // and wider-than-batch pooled scratches must produce BIT-equal
        // logits (not tolerance — the per-row arithmetic is identical).
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 59)));
        let factory = full_factory(&cfg);
        let prompts: Vec<Vec<usize>> = vec![vec![1, 2, 3], vec![4, 5], vec![6, 7]];
        let tokens = [11usize, 12, 13];
        let run = |workers: Workers| {
            let mut states: Vec<SequenceState> = prompts
                .iter()
                .map(|p| {
                    let mut s = SequenceState::new(&cfg, &factory);
                    let mut sc = Scratch::new(&cfg);
                    model.prefill(&mut s, &mut sc, p);
                    s
                })
                .collect();
            let mut scratch = BatchScratch::with_workers(workers);
            let mut refs: Vec<&mut SequenceState> = states.iter_mut().collect();
            model.decode_batch(&mut refs, &tokens, &mut scratch)
        };
        let reference = run(Workers::serial());
        for workers in [Workers::scoped(2), Workers::pooled(2), Workers::pooled(8)] {
            let label = format!("{workers:?}");
            assert_eq!(run(workers), reference, "{label} must be bit-identical");
        }
    }

    #[test]
    #[should_panic(expected = "one token per sequence")]
    fn decode_batch_rejects_shape_mismatch() {
        let cfg = ModelConfig::tiny_mha(32);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 51)));
        let factory = full_factory(&cfg);
        let mut s = SequenceState::new(&cfg, &factory);
        let mut refs: Vec<&mut SequenceState> = vec![&mut s];
        model.decode_batch(&mut refs, &[1, 2], &mut BatchScratch::new(1));
    }

    #[test]
    fn fork_adopt_resumes_decode_identically() {
        // A state adopting a forked prefix must decode bit-identically to
        // a cold-prefilled control, with kv_bytes parity and a nonzero
        // by-reference share.
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 53)));
        let factory = full_factory(&cfg);
        let prompt = [3usize, 1, 4, 1, 5, 9];
        let mut donor = SequenceState::new(&cfg, &factory);
        let mut sc = Scratch::new(&cfg);
        model.prefill(&mut donor, &mut sc, &prompt);
        let snap = donor.fork_prefix(donor.pos).expect("fork full prefix");
        assert_eq!(snap.n_tokens, prompt.len());
        assert!(snap.shared_bytes() > 0);
        assert!(donor.fork_prefix(donor.pos - 1).is_none(), "interior fork unsupported");

        let mut cold = SequenceState::new(&cfg, &factory);
        let mut scc = Scratch::new(&cfg);
        model.prefill(&mut cold, &mut scc, &prompt);

        let mut adopted = SequenceState::new(&cfg, &factory);
        assert!(adopted.adopt_prefix(&snap));
        assert_eq!(adopted.pos, prompt.len());
        assert_eq!(adopted.kv_bytes(), cold.kv_bytes());
        assert_eq!(adopted.shared_prefix_bytes(), snap.shared_bytes());

        let mut sa = Scratch::new(&cfg);
        for tok in [11usize, 12, 13] {
            let la = model.step(&mut adopted, &mut sa, tok, true).unwrap();
            let lc = model.step(&mut cold, &mut scc, tok, true).unwrap();
            assert_eq!(la, lc, "adopted decode must be bit-identical to cold");
        }
        // A state that has already run tokens refuses adoption.
        assert!(!cold.adopt_prefix(&snap));
    }

    #[test]
    fn kv_bytes_grow_with_tokens() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 19)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        model.step(&mut state, &mut scratch, 1, false);
        let b1 = state.kv_bytes();
        model.step(&mut state, &mut scratch, 2, false);
        assert!(state.kv_bytes() > b1);
        let shape: AttnShape = cfg.attn_shape();
        assert_eq!(state.kv_bytes(), 2 * cfg.n_layers * 2 * shape.kv_dim() * 4);
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn rejects_bad_token() {
        let cfg = ModelConfig::tiny_mha(32);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 23)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        model.step(&mut state, &mut scratch, 99_999, false);
    }
}

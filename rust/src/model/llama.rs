//! LLaMA-style decoder forward pass over pluggable attention backends.
//!
//! Weights are shared (`Arc<Weights>`); per-sequence decode state (the KV
//! caches inside each layer's [`AttentionBackend`]) lives in
//! [`SequenceState`]. This split is what lets the coordinator batch many
//! sequences over one weight set, vLLM-style.

use super::config::ModelConfig;
use super::weights::Weights;
use crate::attention::AttentionBackend;
use crate::tensor::ops::{rmsnorm, silu};
use std::sync::Arc;

/// Factory producing one attention backend per layer.
pub type BackendFactory = dyn Fn(usize) -> Box<dyn AttentionBackend + Send> + Send + Sync;

/// Per-sequence decode state: one KV backend per layer + position counter.
pub struct SequenceState {
    pub backends: Vec<Box<dyn AttentionBackend + Send>>,
    pub pos: usize,
}

impl SequenceState {
    pub fn new(cfg: &ModelConfig, factory: &BackendFactory) -> SequenceState {
        SequenceState { backends: (0..cfg.n_layers).map(|l| factory(l)).collect(), pos: 0 }
    }

    /// Total resident KV bytes across layers.
    pub fn kv_bytes(&self) -> usize {
        self.backends.iter().map(|b| b.kv_bytes()).sum()
    }

    /// Total cache traffic across layers.
    pub fn traffic(&self) -> crate::attention::Traffic {
        let mut t = crate::attention::Traffic::default();
        for b in &self.backends {
            let bt = b.traffic();
            t.read += bt.read;
            t.written += bt.written;
        }
        t
    }
}

/// The shared model: config + weights. Stateless across sequences.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: Arc<Weights>,
}

/// Scratch buffers for one forward step (reused across steps).
pub struct Scratch {
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    ffn: Vec<f32>,
}

impl Scratch {
    pub fn new(cfg: &ModelConfig) -> Scratch {
        Scratch {
            x: vec![0.0; cfg.d_model],
            normed: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * cfg.head_dim],
            k: vec![0.0; cfg.kv_dim()],
            v: vec![0.0; cfg.kv_dim()],
            attn_out: vec![0.0; cfg.n_heads * cfg.head_dim],
            proj: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.d_ff],
            up: vec![0.0; cfg.d_ff],
            ffn: vec![0.0; cfg.d_model],
        }
    }
}

/// y = x @ W  for a (d_in, d_out) weight, accumulated into `out`.
fn linear(x: &[f32], w: &crate::tensor::Mat, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.rows);
    debug_assert_eq!(out.len(), w.cols);
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let wrow = &w.data[i * w.cols..(i + 1) * w.cols];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xi * wv;
        }
    }
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Arc<Weights>) -> Model {
        cfg.validate().expect("invalid model config");
        Model { cfg, weights }
    }

    /// One decode step: feed `token`, advance `state`, return logits.
    ///
    /// `process_only`: during prefill we still must append KV and run the
    /// layers (the residual stream feeds later keys), but logits can be
    /// skipped; pass `false` to skip the LM head.
    pub fn step(&self, state: &mut SequenceState, scratch: &mut Scratch, token: usize, want_logits: bool) -> Option<Vec<f32>> {
        let cfg = &self.cfg;
        let w = &self.weights;
        assert!(token < cfg.vocab, "token {token} out of vocab");
        assert!(state.pos < cfg.max_seq, "sequence exceeds max_seq");

        // Embed.
        scratch.x.copy_from_slice(w.embedding.row(token));

        for (layer, lw) in w.layers.iter().enumerate() {
            // ---- attention block ----
            rmsnorm(&scratch.x, &lw.norm_attn, cfg.rms_eps, &mut scratch.normed);
            linear(&scratch.normed, &lw.wq, &mut scratch.q);
            linear(&scratch.normed, &lw.wk, &mut scratch.k);
            linear(&scratch.normed, &lw.wv, &mut scratch.v);
            let backend = &mut state.backends[layer];
            backend.append(&scratch.k, &scratch.v);
            backend.attend(&scratch.q, &mut scratch.attn_out);
            linear(&scratch.attn_out, &lw.wo, &mut scratch.proj);
            for (xi, pi) in scratch.x.iter_mut().zip(&scratch.proj) {
                *xi += pi;
            }
            // ---- FFN block (SwiGLU) ----
            rmsnorm(&scratch.x, &lw.norm_ffn, cfg.rms_eps, &mut scratch.normed);
            linear(&scratch.normed, &lw.w_gate, &mut scratch.gate);
            linear(&scratch.normed, &lw.w_up, &mut scratch.up);
            for (g, u) in scratch.gate.iter_mut().zip(&scratch.up) {
                *g = silu(*g) * u;
            }
            linear(&scratch.gate, &lw.w_down, &mut scratch.ffn);
            for (xi, fi) in scratch.x.iter_mut().zip(&scratch.ffn) {
                *xi += fi;
            }
        }
        state.pos += 1;

        if !want_logits {
            return None;
        }
        // Final norm + tied LM head.
        rmsnorm(&scratch.x, &w.norm_final, cfg.rms_eps, &mut scratch.normed);
        let mut logits = vec![0.0f32; cfg.vocab];
        // logits = E @ normed (E rows are embeddings).
        for (t, l) in logits.iter_mut().enumerate() {
            *l = crate::tensor::ops::dot(w.embedding.row(t), &scratch.normed);
        }
        Some(logits)
    }

    /// Run a full prompt, returning logits after the last token.
    pub fn prefill(&self, state: &mut SequenceState, scratch: &mut Scratch, tokens: &[usize]) -> Vec<f32> {
        assert!(!tokens.is_empty());
        for &t in &tokens[..tokens.len() - 1] {
            self.step(state, scratch, t, false);
        }
        self.step(state, scratch, tokens[tokens.len() - 1], true).unwrap()
    }

    /// Greedy generation of `n` tokens after a prompt.
    pub fn generate_greedy(
        &self,
        state: &mut SequenceState,
        scratch: &mut Scratch,
        prompt: &[usize],
        n: usize,
    ) -> Vec<usize> {
        let mut logits = self.prefill(state, scratch, prompt);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let next = crate::tensor::ops::argmax(&logits);
            out.push(next);
            if state.pos >= self.cfg.max_seq {
                break;
            }
            logits = self.step(state, scratch, next, true).unwrap();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{AttnShape, FullAttention};

    fn full_factory(cfg: &ModelConfig) -> Box<BackendFactory> {
        let shape = cfg.attn_shape();
        Box::new(move |_layer| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>)
    }

    #[test]
    fn decode_produces_finite_logits() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 11)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        let logits = model.prefill(&mut state, &mut scratch, &[1, 2, 3, 4, 5]);
        assert_eq!(logits.len(), cfg.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(state.pos, 5);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 13)));
        let factory = full_factory(&cfg);
        let run = || {
            let mut state = SequenceState::new(&cfg, &factory);
            let mut scratch = Scratch::new(&cfg);
            model.generate_greedy(&mut state, &mut scratch, &[7, 8, 9], 5)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn per_token_decode_matches_prefill_path() {
        // prefill() is just repeated step(); verify logits equivalence by
        // construction: run the same tokens manually.
        let cfg = ModelConfig::tiny_gqa(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 17)));
        let factory = full_factory(&cfg);
        let tokens = [3usize, 1, 4, 1, 5];
        let mut s1 = SequenceState::new(&cfg, &factory);
        let mut sc1 = Scratch::new(&cfg);
        let a = model.prefill(&mut s1, &mut sc1, &tokens);
        let mut s2 = SequenceState::new(&cfg, &factory);
        let mut sc2 = Scratch::new(&cfg);
        let mut b = None;
        for (i, &t) in tokens.iter().enumerate() {
            b = model.step(&mut s2, &mut sc2, t, i == tokens.len() - 1);
        }
        assert_eq!(a, b.unwrap());
    }

    #[test]
    fn kv_bytes_grow_with_tokens() {
        let cfg = ModelConfig::tiny_mha(64);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 19)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        model.step(&mut state, &mut scratch, 1, false);
        let b1 = state.kv_bytes();
        model.step(&mut state, &mut scratch, 2, false);
        assert!(state.kv_bytes() > b1);
        let shape: AttnShape = cfg.attn_shape();
        assert_eq!(state.kv_bytes(), 2 * cfg.n_layers * 2 * shape.kv_dim() * 4);
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn rejects_bad_token() {
        let cfg = ModelConfig::tiny_mha(32);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 23)));
        let factory = full_factory(&cfg);
        let mut state = SequenceState::new(&cfg, &factory);
        let mut scratch = Scratch::new(&cfg);
        model.step(&mut state, &mut scratch, 99_999, false);
    }
}

//! Method registry: build per-layer attention backends for every method in
//! the paper's comparison set, plus the offline calibration pass that fits
//! the latent projectors / channel sets they need.

use super::config::ModelConfig;
use super::llama::{BackendFactory, Model, Scratch, SequenceState};
use crate::attention::baselines::double_sparse::DoubleSparseAttention;
use crate::attention::baselines::hshare::HShareAttention;
use crate::attention::baselines::kivi::KiviAttention;
use crate::attention::baselines::loki::LokiAttention;
use crate::attention::baselines::palu::PaluAttention;
use crate::attention::baselines::quest::QuestAttention;
use crate::attention::baselines::streaming_llm::StreamingLlmAttention;
use crate::attention::{AttentionBackend, FullAttention, SalsAttention, SalsConfig, Traffic};
use crate::lowrank::{Calibrator, Projector};
use crate::quant::Bits;
use crate::rope::RopeTable;
use crate::tensor::Mat;
use std::sync::Arc;

/// Token-selection composition shared by the sparse methods (§5.2).
#[derive(Clone, Copy, Debug)]
pub struct SparsityParams {
    pub sink: usize,
    pub recent: usize,
    pub critical: usize,
}

impl SparsityParams {
    /// Paper's LongBench config for LLaMA2: x=16, y=432, z=64 (scaled down
    /// proportionally for small max_seq in tests/benches).
    pub fn paper_llama2() -> SparsityParams {
        SparsityParams { sink: 16, recent: 64, critical: 432 }
    }

    /// Scale the composition to a target sequence length, keeping the
    /// 16:432:64 proportions of the paper at sparsity 1/8.
    pub fn scaled(seq: usize) -> SparsityParams {
        let total = (seq / 8).max(8);
        SparsityParams {
            sink: (total * 16 / 512).max(1),
            recent: (total * 64 / 512).max(2),
            critical: (total * 432 / 512).max(4),
        }
    }
}

/// Every attention method in the comparison matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Full,
    /// SALS at 25% key compression (4-bit values).
    Sals25,
    /// SALS at 12.5% key compression (2-bit values).
    Sals125,
    Kivi4,
    Kivi2,
    /// Palu at 30% rank (with 4-bit latent quant, nearest to paper's 3-bit).
    Palu30,
    /// Palu at 50% rank reduction (rank = 50% ... paper's "Palu-50%" keeps
    /// 50% compression ratio; see table mapping in DESIGN.md).
    Palu50,
    Loki,
    DoubleSparse,
    HShare,
    Quest,
    StreamingLlm,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Full => "baseline",
            Method::Sals25 => "SALS-25%",
            Method::Sals125 => "SALS-12.5%",
            Method::Kivi4 => "KIVI-4bit",
            Method::Kivi2 => "KIVI-2bit",
            Method::Palu30 => "Palu-30%",
            Method::Palu50 => "Palu-50%",
            Method::Loki => "Loki",
            Method::DoubleSparse => "Double Sparse",
            Method::HShare => "HShare",
            Method::Quest => "Quest",
            Method::StreamingLlm => "StreamingLLM",
        }
    }

    /// All methods compared in the accuracy tables.
    pub fn accuracy_set() -> Vec<Method> {
        vec![
            Method::Full,
            Method::Kivi4,
            Method::Kivi2,
            Method::Palu30,
            Method::Palu50,
            Method::Sals25,
            Method::Sals125,
        ]
    }

    /// Token-sparse comparison set (Table 4).
    pub fn sparse_set() -> Vec<Method> {
        vec![
            Method::Full,
            Method::DoubleSparse,
            Method::HShare,
            Method::Loki,
            Method::Sals25,
            Method::Sals125,
        ]
    }
}

/// Per-layer calibration tensors collected with the recording pass.
#[derive(Clone, Debug)]
pub struct LayerCalibration {
    /// (n_tokens, kv_dim) pre-RoPE keys.
    pub pre_keys: Mat,
    /// (n_tokens, kv_dim) post-RoPE keys.
    pub post_keys: Mat,
    /// (n_tokens, kv_dim) values.
    pub values: Mat,
}

/// Calibration output for all layers.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub layers: Vec<LayerCalibration>,
}

/// A FullAttention wrapper that records pre-RoPE keys/values as they stream
/// through — the §4.2 "collect pre-RoPE key tensors" pass. Recordings land
/// in a shared per-layer sink so `calibrate` can read them back without
/// downcasting.
type RecordSink = Arc<std::sync::Mutex<(Vec<f32>, Vec<f32>)>>;

struct RecordingBackend {
    inner: FullAttention,
    sink: RecordSink,
}

impl AttentionBackend for RecordingBackend {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let mut guard = self.sink.lock().unwrap();
        guard.0.extend_from_slice(k);
        guard.1.extend_from_slice(v);
        drop(guard);
        self.inner.append(k, v);
    }
    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        self.inner.attend(q, out);
    }
    fn len(&self) -> usize {
        self.inner.len()
    }
    fn traffic(&self) -> Traffic {
        self.inner.traffic()
    }
    fn kv_bytes(&self) -> usize {
        self.inner.kv_bytes()
    }
    fn footprint(&self) -> crate::attention::FootprintModel {
        self.inner.footprint()
    }
    fn name(&self) -> &'static str {
        "recording"
    }
    fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }
}

/// Run the model over calibration token streams with recording backends and
/// collect per-layer pre/post-RoPE keys and values.
pub fn calibrate(model: &Model, streams: &[Vec<usize>]) -> Calibration {
    let cfg = &model.cfg;
    let kvd = cfg.kv_dim();
    let shape = cfg.attn_shape();
    let rope = RopeTable::new(cfg.head_dim, cfg.max_seq, cfg.rope_base);
    let mut layers: Vec<LayerCalibration> = (0..cfg.n_layers)
        .map(|_| LayerCalibration {
            pre_keys: Mat::zeros(0, kvd),
            post_keys: Mat::zeros(0, kvd),
            values: Mat::zeros(0, kvd),
        })
        .collect();

    for stream in streams {
        let sinks: Vec<RecordSink> =
            (0..cfg.n_layers).map(|_| Arc::new(std::sync::Mutex::new((Vec::new(), Vec::new())))).collect();
        let sinks_for_factory = sinks.clone();
        let factory: Box<BackendFactory> = Box::new(move |layer| {
            Box::new(RecordingBackend {
                inner: FullAttention::new(shape),
                sink: Arc::clone(&sinks_for_factory[layer]),
            }) as Box<dyn AttentionBackend + Send>
        });
        let mut state = SequenceState::new(cfg, &factory);
        let mut scratch = Scratch::new(cfg);
        for &t in stream {
            model.step(&mut state, &mut scratch, t, false);
        }
        drop(state);
        for (layer, sink) in sinks.into_iter().enumerate() {
            let (pre_keys, values) = {
                let mut g = sink.lock().unwrap();
                (std::mem::take(&mut g.0), std::mem::take(&mut g.1))
            };
            let n = pre_keys.len() / kvd;
            let lc = &mut layers[layer];
            lc.pre_keys.data.extend_from_slice(&pre_keys);
            lc.pre_keys.rows += n;
            lc.values.data.extend_from_slice(&values);
            lc.values.rows += n;
            // Post-RoPE keys: rotate each row at its in-stream position.
            let mut rot = pre_keys;
            for (pos, row) in rot.chunks_exact_mut(kvd).enumerate() {
                rope.apply_rows_at(row, kvd, &[pos]);
            }
            lc.post_keys.data.extend_from_slice(&rot);
            lc.post_keys.rows += n;
        }
    }
    Calibration { layers }
}

/// Per-layer artifacts fitted from a [`Calibration`], enough to build any
/// method's backends.
pub struct FittedCalibration {
    pub cfg: ModelConfig,
    /// Joint pre-RoPE key projectors at the FULL kv_dim rank (slice to any
    /// smaller r at build time).
    pub pre_key_proj: Vec<Arc<Projector>>,
    /// Post-RoPE key projectors (Loki).
    pub post_key_proj: Vec<Arc<Projector>>,
    /// Value projectors (Palu).
    pub value_proj: Vec<Arc<Projector>>,
    /// DoubleSparse important channels per layer.
    pub ds_channels: Vec<Vec<usize>>,
}

/// Fit all per-layer projectors/channel sets once.
pub fn fit_calibration(cfg: &ModelConfig, calib: &Calibration) -> FittedCalibration {
    let kvd = cfg.kv_dim();
    let mut pre = Vec::new();
    let mut post = Vec::new();
    let mut val = Vec::new();
    let mut ds = Vec::new();
    for lc in &calib.layers {
        let mut c1 = Calibrator::new(kvd);
        c1.add_keys(&lc.pre_keys.data);
        pre.push(Arc::new(c1.fit(kvd).expect("pre-key fit")));
        let mut c2 = Calibrator::new(kvd);
        c2.add_keys(&lc.post_keys.data);
        post.push(Arc::new(c2.fit(kvd).expect("post-key fit")));
        let mut c3 = Calibrator::new(kvd);
        c3.add_keys(&lc.values.data);
        val.push(Arc::new(c3.fit(kvd).expect("value fit")));
        ds.push(DoubleSparseAttention::select_channels(&lc.post_keys.data, kvd, (kvd / 8).max(2)));
    }
    FittedCalibration { cfg: cfg.clone(), pre_key_proj: pre, post_key_proj: post, value_proj: val, ds_channels: ds }
}

/// Truncate a full-rank projector to rank r (leading columns).
fn slice_projector(p: &Projector, r: usize) -> Projector {
    assert!(r <= p.rank);
    let mut u = Mat::zeros(p.dim, r);
    for row in 0..p.dim {
        for col in 0..r {
            u.data[row * r + col] = p.u.data[row * p.rank + col];
        }
    }
    Projector { dim: p.dim, rank: r, u, spectrum: p.spectrum.clone() }
}

/// Build a per-layer backend factory for `method`. Layers in
/// `cfg.dense_layers` always get dense attention (paper §5.1: layers 0, 1
/// and the last are skipped for sparsification).
pub fn make_factory(
    method: Method,
    fitted: &Arc<FittedCalibration>,
    sp: SparsityParams,
) -> Box<BackendFactory> {
    let fitted = Arc::clone(fitted);
    let cfg = fitted.cfg.clone();
    let shape = cfg.attn_shape();
    let kvd = cfg.kv_dim();
    Box::new(move |layer| {
        let dense = cfg.dense_layers.contains(&layer) && method != Method::Full;
        if method == Method::Full || (dense && !matches!(method, Method::Kivi4 | Method::Kivi2)) {
            // Quantization methods apply to all layers in the paper; the
            // layer-skip rule is about *sparsification*.
            return Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>;
        }
        match method {
            Method::Full => unreachable!(),
            Method::Sals25 => {
                let r = (kvd / 4).max(2);
                let proj = slice_projector(&fitted.pre_key_proj[layer], r);
                let c = SalsConfig {
                    rank: r,
                    r_star: (r / 2).max(1),
                    sink: sp.sink,
                    recent: sp.recent,
                    critical: sp.critical,
                    v_bits: Bits::B4,
                    group: 32,
                    prefill: None,
                };
                Box::new(SalsAttention::new(shape, c, proj))
            }
            Method::Sals125 => {
                let r = (kvd / 8).max(2);
                let proj = slice_projector(&fitted.pre_key_proj[layer], r);
                let c = SalsConfig {
                    rank: r,
                    r_star: (r / 2).max(1),
                    sink: sp.sink,
                    recent: sp.recent,
                    critical: sp.critical,
                    v_bits: Bits::B2,
                    group: 32,
                    prefill: None,
                };
                Box::new(SalsAttention::new(shape, c, proj))
            }
            Method::Kivi4 => Box::new(KiviAttention::new(shape, Bits::B4, 32, sp.recent.max(32))),
            Method::Kivi2 => Box::new(KiviAttention::new(shape, Bits::B2, 32, sp.recent.max(32))),
            Method::Palu30 => {
                // 30% compression of the fp16 cache with 3-bit quant in the
                // paper; here: rank 0.6·kvd with 4-bit latents (DESIGN.md).
                let r = (kvd * 6 / 10).max(2);
                let kp = slice_projector(&fitted.pre_key_proj[layer], r);
                let vp = slice_projector(&fitted.value_proj[layer], r);
                Box::new(PaluAttention::new(shape, kp, vp, r, Some(Bits::B4)))
            }
            Method::Palu50 => {
                let r = (kvd * 3 / 10).max(2);
                let kp = slice_projector(&fitted.pre_key_proj[layer], r);
                let vp = slice_projector(&fitted.value_proj[layer], r);
                Box::new(PaluAttention::new(shape, kp, vp, r, Some(Bits::B4)))
            }
            Method::Loki => {
                let r = (kvd / 4).max(2);
                let proj = slice_projector(&fitted.post_key_proj[layer], r);
                Box::new(LokiAttention::new(shape, proj, r, sp.sink, sp.recent, sp.critical))
            }
            Method::DoubleSparse => Box::new(DoubleSparseAttention::new(
                shape,
                fitted.ds_channels[layer].clone(),
                sp.sink,
                sp.recent,
                sp.critical,
            )),
            Method::HShare => Box::new(HShareAttention::new(shape, sp.sink, sp.recent, sp.critical, 4)),
            Method::Quest => Box::new(QuestAttention::new(shape, 16, sp.sink, sp.recent, sp.critical)),
            Method::StreamingLlm => Box::new(StreamingLlmAttention::new(shape, sp.sink, sp.recent + sp.critical)),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn small_setup() -> (Model, Arc<FittedCalibration>) {
        let mut cfg = ModelConfig::tiny_mha(128);
        cfg.n_layers = 3;
        cfg.dense_layers = vec![0];
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 29)));
        let mut rng = Rng::new(31);
        let streams: Vec<Vec<usize>> =
            (0..4).map(|_| (0..64).map(|_| rng.below(cfg.vocab)).collect()).collect();
        let calib = calibrate(&model, &streams);
        let fitted = Arc::new(fit_calibration(&cfg, &calib));
        (model, fitted)
    }

    #[test]
    fn calibration_collects_all_layers_and_tokens() {
        let (model, fitted) = small_setup();
        assert_eq!(fitted.pre_key_proj.len(), model.cfg.n_layers);
        // 4 streams × 64 tokens
        assert_eq!(fitted.pre_key_proj[0].dim, model.cfg.kv_dim());
    }

    #[test]
    fn every_method_generates() {
        let (model, fitted) = small_setup();
        let sp = SparsityParams { sink: 2, recent: 8, critical: 8 };
        for method in [
            Method::Full,
            Method::Sals25,
            Method::Sals125,
            Method::Kivi4,
            Method::Kivi2,
            Method::Palu30,
            Method::Palu50,
            Method::Loki,
            Method::DoubleSparse,
            Method::HShare,
            Method::Quest,
            Method::StreamingLlm,
        ] {
            let factory = make_factory(method, &fitted, sp);
            let mut state = SequenceState::new(&model.cfg, &factory);
            let mut scratch = Scratch::new(&model.cfg);
            let out = model.generate_greedy(&mut state, &mut scratch, &[1, 2, 3, 4], 4);
            assert_eq!(out.len(), 4, "{method:?}");
        }
    }

    #[test]
    fn dense_layers_get_full_attention() {
        let (_, fitted) = small_setup();
        let sp = SparsityParams { sink: 1, recent: 2, critical: 2 };
        let factory = make_factory(Method::Sals25, &fitted, sp);
        assert_eq!(factory(0).name(), "full"); // layer 0 is dense
        assert_eq!(factory(1).name(), "sals");
    }

    #[test]
    fn sals_outputs_close_to_full_on_same_prompt() {
        let (model, fitted) = small_setup();
        let sp = SparsityParams { sink: 4, recent: 16, critical: 24 };
        let prompt: Vec<usize> = (0..48).map(|i| (i * 7 + 3) % model.cfg.vocab).collect();
        let run = |m: Method| {
            let factory = make_factory(m, &fitted, sp);
            let mut state = SequenceState::new(&model.cfg, &factory);
            let mut scratch = Scratch::new(&model.cfg);
            model.prefill(&mut state, &mut scratch, &prompt)
        };
        let full = run(Method::Full);
        let sals = run(Method::Sals25);
        let cos = crate::util::stats::cosine(&sals, &full);
        assert!(cos > 0.8, "logit cosine {cos}");
    }
}

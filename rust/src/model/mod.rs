//! CPU reference model: LLaMA-style decoder with pluggable per-layer
//! attention backends, the offline calibration pass, the method registry,
//! and the constructed retrieval model for accuracy-proxy experiments.

pub mod backends;
pub mod config;
pub mod llama;
pub mod retrieval;
pub mod weights;

pub use backends::{calibrate, fit_calibration, make_factory, Calibration, FittedCalibration, Method, SparsityParams};
pub use config::ModelConfig;
pub use llama::{
    BackendFactory, BatchScratch, Model, Scratch, SequenceFootprint, SequenceSnapshot,
    SequenceState,
};
pub use weights::Weights;

//! Model weights: seeded random initialization and the constructed
//! retrieval circuit used for accuracy-proxy experiments.

use super::config::ModelConfig;
use crate::tensor::Mat;
use crate::util::rng::Rng;

/// Per-layer weights.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    /// (d_model, n_heads*head_dim)
    pub wq: Mat,
    /// (d_model, kv_dim)
    pub wk: Mat,
    /// (d_model, kv_dim)
    pub wv: Mat,
    /// (n_heads*head_dim, d_model)
    pub wo: Mat,
    /// (d_model, d_ff)
    pub w_gate: Mat,
    /// (d_model, d_ff)
    pub w_up: Mat,
    /// (d_ff, d_model)
    pub w_down: Mat,
    /// (d_model,) attention-input RMSNorm weight
    pub norm_attn: Vec<f32>,
    /// (d_model,) FFN-input RMSNorm weight
    pub norm_ffn: Vec<f32>,
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    /// (vocab, d_model) token embedding; also the (tied) LM head.
    pub embedding: Mat,
    pub layers: Vec<LayerWeights>,
    /// (d_model,) final RMSNorm weight.
    pub norm_final: Vec<f32>,
}

impl Weights {
    /// Standard scaled-Gaussian init (seeded, deterministic).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let dm = cfg.d_model;
        let kvd = cfg.kv_dim();
        let std = 1.0 / (dm as f32).sqrt();
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                wq: Mat::randn(dm, cfg.n_heads * cfg.head_dim, std, &mut rng),
                wk: Mat::randn(dm, kvd, std, &mut rng),
                wv: Mat::randn(dm, kvd, std, &mut rng),
                wo: Mat::randn(cfg.n_heads * cfg.head_dim, dm, std, &mut rng),
                w_gate: Mat::randn(dm, cfg.d_ff, std, &mut rng),
                w_up: Mat::randn(dm, cfg.d_ff, std, &mut rng),
                w_down: Mat::randn(cfg.d_ff, dm, 1.0 / (cfg.d_ff as f32).sqrt(), &mut rng),
                norm_attn: vec![1.0; dm],
                norm_ffn: vec![1.0; dm],
            })
            .collect();
        Weights {
            embedding: Mat::randn(cfg.vocab, dm, 1.0, &mut rng),
            layers,
            norm_final: vec![1.0; dm],
        }
    }

    /// Like [`Weights::random`] but with low-rank key projections
    /// (`wk = A·B`, inner rank `key_rank`). Real LLMs' pre-RoPE keys are
    /// empirically low-rank (the §2.1 premise); plain Gaussian wk would be
    /// full-rank and unrepresentative for calibration/rank analyses.
    pub fn random_lowrank_keys(cfg: &ModelConfig, seed: u64, key_rank: usize) -> Weights {
        let mut w = Weights::random(cfg, seed);
        let mut rng = Rng::new(seed ^ 0x10F0);
        let kvd = cfg.kv_dim();
        let std = 1.0 / (cfg.d_model as f32).sqrt();
        for l in &mut w.layers {
            let a = Mat::randn(cfg.d_model, key_rank, std, &mut rng);
            let b = Mat::randn(key_rank, kvd, 1.0 / (key_rank as f32).sqrt(), &mut rng);
            l.wk = a.matmul(&b);
        }
        w
    }

    /// Rough memory footprint in bytes.
    pub fn nbytes(&self) -> usize {
        let mut n = self.embedding.data.len() + self.norm_final.len();
        for l in &self.layers {
            n += l.wq.data.len()
                + l.wk.data.len()
                + l.wv.data.len()
                + l.wo.data.len()
                + l.w_gate.data.len()
                + l.w_up.data.len()
                + l.w_down.data.len()
                + l.norm_attn.len()
                + l.norm_ffn.len();
        }
        n * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_deterministic() {
        let cfg = ModelConfig::tiny_mha(64);
        let a = Weights::random(&cfg, 7);
        let b = Weights::random(&cfg, 7);
        assert_eq!(a.embedding.data, b.embedding.data);
        assert_eq!(a.layers[3].w_down.data, b.layers[3].w_down.data);
        let c = Weights::random(&cfg, 8);
        assert_ne!(a.embedding.data, c.embedding.data);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ModelConfig::tiny_gqa(64);
        let w = Weights::random(&cfg, 1);
        assert_eq!(w.layers.len(), cfg.n_layers);
        let l = &w.layers[0];
        assert_eq!((l.wq.rows, l.wq.cols), (cfg.d_model, cfg.n_heads * cfg.head_dim));
        assert_eq!((l.wk.rows, l.wk.cols), (cfg.d_model, cfg.kv_dim()));
        assert_eq!((l.wo.rows, l.wo.cols), (cfg.n_heads * cfg.head_dim, cfg.d_model));
        assert_eq!((l.w_down.rows, l.w_down.cols), (cfg.d_ff, cfg.d_model));
    }
}

//! Model configuration (LLaMA-family decoder).

use crate::attention::AttnShape;
use crate::util::{Error, Result};

/// Architecture hyper-parameters of the CPU reference model.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_base: f32,
    /// Layers that skip sparsification and run dense attention
    /// (paper §5.1: layers 0, 1 and the last layer).
    pub dense_layers: Vec<usize>,
    pub rms_eps: f32,
}

impl ModelConfig {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.n_heads % self.n_kv_heads != 0 {
            return Err(Error::Config("n_heads must be divisible by n_kv_heads".into()));
        }
        if self.head_dim % 2 != 0 {
            return Err(Error::Config("head_dim must be even for RoPE".into()));
        }
        if self.d_model != self.n_heads * self.head_dim {
            return Err(Error::Config(format!(
                "d_model {} != n_heads*head_dim {}",
                self.d_model,
                self.n_heads * self.head_dim
            )));
        }
        if self.dense_layers.iter().any(|&l| l >= self.n_layers) {
            return Err(Error::Config("dense layer index out of range".into()));
        }
        Ok(())
    }

    /// Attention shape of each layer.
    pub fn attn_shape(&self) -> AttnShape {
        AttnShape {
            n_heads: self.n_heads,
            n_kv_heads: self.n_kv_heads,
            head_dim: self.head_dim,
            max_seq: self.max_seq,
            rope_base: self.rope_base,
        }
    }

    /// Stacked KV dimension.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// The paper's default dense-layer set: {0, 1, last}.
    pub fn default_dense_layers(n_layers: usize) -> Vec<usize> {
        if n_layers >= 3 {
            vec![0, 1, n_layers - 1]
        } else {
            (0..n_layers).collect()
        }
    }

    /// A small MHA config in the LLaMA2 shape family (scaled down).
    pub fn tiny_mha(max_seq: usize) -> ModelConfig {
        ModelConfig {
            vocab: 512,
            d_model: 128,
            n_layers: 6,
            n_heads: 4,
            n_kv_heads: 4,
            head_dim: 32,
            d_ff: 256,
            max_seq,
            rope_base: 10_000.0,
            dense_layers: Self::default_dense_layers(6),
            rms_eps: 1e-5,
        }
    }

    /// A small GQA config in the Mistral shape family (scaled down).
    pub fn tiny_gqa(max_seq: usize) -> ModelConfig {
        ModelConfig {
            vocab: 512,
            d_model: 128,
            n_layers: 6,
            n_heads: 8,
            n_kv_heads: 2,
            head_dim: 16,
            d_ff: 256,
            max_seq,
            rope_base: 10_000.0,
            dense_layers: Self::default_dense_layers(6),
            rms_eps: 1e-5,
        }
    }

    /// ~100M-parameter class config for the end-to-end driver (GPT-fast
    /// comparison scale, Table 7): 12 layers, d_model 768.
    pub fn medium(max_seq: usize) -> ModelConfig {
        ModelConfig {
            vocab: 4096,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            n_kv_heads: 12,
            head_dim: 64,
            d_ff: 2048,
            max_seq,
            rope_base: 10_000.0,
            dense_layers: Self::default_dense_layers(12),
            rms_eps: 1e-5,
        }
    }

    /// Approximate parameter count.
    pub fn param_count(&self) -> usize {
        let attn = self.d_model * self.d_model // wq
            + 2 * self.d_model * self.kv_dim() // wk, wv
            + self.d_model * self.d_model; // wo
        let ffn = 3 * self.d_model * self.d_ff;
        let norms = 2 * self.d_model;
        self.vocab * self.d_model + self.n_layers * (attn + ffn + norms) + self.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_configs_valid() {
        ModelConfig::tiny_mha(256).validate().unwrap();
        ModelConfig::tiny_gqa(256).validate().unwrap();
        ModelConfig::medium(512).validate().unwrap();
    }

    #[test]
    fn invalid_heads_rejected() {
        let mut c = ModelConfig::tiny_mha(128);
        c.n_kv_heads = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn invalid_d_model_rejected() {
        let mut c = ModelConfig::tiny_mha(128);
        c.d_model = 100;
        assert!(c.validate().is_err());
    }

    #[test]
    fn medium_is_roughly_100m() {
        let p = ModelConfig::medium(512).param_count();
        assert!(p > 60_000_000 && p < 200_000_000, "{p}");
    }

    #[test]
    fn default_dense_layers_small() {
        assert_eq!(ModelConfig::default_dense_layers(2), vec![0, 1]);
        assert_eq!(ModelConfig::default_dense_layers(8), vec![0, 1, 7]);
    }
}

//! Constructed retrieval transformer — the accuracy-proxy substrate.
//!
//! No 7B checkpoints exist in this sandbox, so Tables 2–5 need a model whose
//! task accuracy *really* depends on attention finding the right tokens.
//! This module hand-constructs a LLaMA-architecture model that provably
//! solves needle-retrieval:
//!
//! * Vocabulary: needle tokens `(key, value)`, query tokens `key`, and
//!   filler tokens.
//! * Embedding uses three disjoint 32-dim subspaces (one per head):
//!   Q (dims 0..32), K (32..64), V (64..96). A needle carries its key
//!   signature κ_k in K and its value signature ν_v in V; a query carries
//!   κ_k in Q **only** (so it never matches itself); fillers carry weak
//!   noise in K (distractor keys).
//! * Head 0 is the content-matching circuit: Wq = α·P_Q, Wk = P_K,
//!   Wv = P_V, Wo writes back to V. At the final query token, attention
//!   mass lands on the needle whose κ matches, copying its ν into the
//!   residual stream; the tied LM head then ranks needle
//!   `(key_q, value*)` highest. Heads 1–2 and the FFN are zero.
//! * RoPE-robustness: key signatures occupy only the slow-rotating RoPE
//!   dimension pairs (high-index pairs), so content matching survives
//!   rotation across the full context (DESIGN.md §3).
//!
//! Accuracy of a compressed method = fraction of queries whose argmax logit
//! is the correct needle token — exactly what RULER/LongBench-style
//! retrieval benchmarks measure, with exact ground truth.

use super::config::ModelConfig;
use super::weights::{LayerWeights, Weights};
use crate::tensor::Mat;
use crate::util::rng::Rng;

/// Builder parameters for the constructed retrieval model.
#[derive(Clone, Debug)]
pub struct RetrievalSpec {
    pub n_keys: usize,
    pub n_vals: usize,
    pub n_fill: usize,
    /// Query-side sharpness multiplier α.
    pub alpha: f32,
    pub n_layers: usize,
    pub max_seq: usize,
    /// Filler key-signature scale (0 = inert fillers).
    pub fill_scale: f32,
    /// Dimensionality of the value-signature subspace (≤ 32). Smaller =
    /// more crowded value codes = more sensitive to cache quantization and
    /// reconstruction noise (the knob that makes compression measurable).
    pub val_dim: usize,
    /// Grouped-query variant: 6 query heads over 3 KV heads (Mistral-style)
    /// instead of 3/3 MHA (LLaMA-style).
    pub gqa: bool,
    pub seed: u64,
}

impl Default for RetrievalSpec {
    fn default() -> RetrievalSpec {
        RetrievalSpec {
            n_keys: 64,
            n_vals: 64,
            n_fill: 128,
            alpha: 64.0,
            n_layers: 6,
            max_seq: 4096,
            fill_scale: 0.3,
            val_dim: HEAD_DIM,
            gqa: false,
            seed: 0xBEEF,
        }
    }
}

/// Constructed model + vocabulary codec.
pub struct RetrievalModel {
    pub cfg: ModelConfig,
    pub weights: Weights,
    pub spec: RetrievalSpec,
}

/// Head geometry: d_model 96, three heads of 32; head 0 carries the circuit.
const D_MODEL: usize = 96;
const HEAD_DIM: usize = 32;
/// Subspace offsets in the residual stream.
const Q_OFF: usize = 0;
const K_OFF: usize = 32;
const V_OFF: usize = 64;
/// Head-0 dims that rotate slowly under RoPE (pairs 8..16 of head_dim 32).
const SLOW_DIMS: [usize; 16] = [8, 9, 10, 11, 12, 13, 14, 15, 24, 25, 26, 27, 28, 29, 30, 31];

impl RetrievalModel {
    pub fn build(spec: RetrievalSpec) -> RetrievalModel {
        let vocab = spec.n_keys * spec.n_vals + spec.n_keys + spec.n_fill;
        // GQA variant widens the residual stream so 6 query heads fit; the
        // circuit subspaces stay at the same offsets, dims beyond 96 unused.
        let (n_heads, n_kv_heads, d_model) = if spec.gqa { (6, 3, 192) } else { (3, 3, D_MODEL) };
        let cfg = ModelConfig {
            vocab,
            d_model,
            n_layers: spec.n_layers,
            n_heads,
            n_kv_heads,
            head_dim: HEAD_DIM,
            d_ff: 4,
            max_seq: spec.max_seq,
            rope_base: 1.0e8, // slow pairs rotate <0.5 rad over 32k tokens
            dense_layers: ModelConfig::default_dense_layers(spec.n_layers),
            rms_eps: 1e-5,
        };
        cfg.validate().unwrap();
        let mut rng = Rng::new(spec.seed);

        // --- signatures ---
        let unit = |rng: &mut Rng, n: usize| {
            let mut v = rng.normal_vec(n, 1.0);
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            for x in &mut v {
                *x /= norm;
            }
            v
        };
        assert!(spec.val_dim >= 1 && spec.val_dim <= HEAD_DIM);
        let keys_sig: Vec<Vec<f32>> = (0..spec.n_keys).map(|_| unit(&mut rng, SLOW_DIMS.len())).collect();
        let vals_sig: Vec<Vec<f32>> = (0..spec.n_vals).map(|_| unit(&mut rng, spec.val_dim)).collect();
        let fill_sig: Vec<Vec<f32>> = (0..spec.n_fill).map(|_| unit(&mut rng, SLOW_DIMS.len())).collect();

        // --- embedding ---
        let dm = cfg.d_model;
        let q_dim = cfg.n_heads * HEAD_DIM;
        let kv_dim = cfg.kv_dim();
        let mut embedding = Mat::zeros(vocab, dm);
        // Needles: K-subspace = κ_k on slow dims; V-subspace = ν_v.
        for k in 0..spec.n_keys {
            for v in 0..spec.n_vals {
                let t = k * spec.n_vals + v;
                let row = embedding.row_mut(t);
                for (s, &d) in SLOW_DIMS.iter().enumerate() {
                    row[K_OFF + d] = keys_sig[k][s];
                }
                for (i, &x) in vals_sig[v].iter().enumerate() {
                    row[V_OFF + i] = x;
                }
            }
        }
        // Queries: Q-subspace only (never self-matches: no K content).
        for k in 0..spec.n_keys {
            let t = spec.n_keys * spec.n_vals + k;
            let row = embedding.row_mut(t);
            for (s, &d) in SLOW_DIMS.iter().enumerate() {
                row[Q_OFF + d] = keys_sig[k][s];
            }
        }
        // Fillers: weak K noise (distractor keys).
        for f in 0..spec.n_fill {
            let t = spec.n_keys * spec.n_vals + spec.n_keys + f;
            let row = embedding.row_mut(t);
            for (s, &d) in SLOW_DIMS.iter().enumerate() {
                row[K_OFF + d] = fill_sig[f][s] * spec.fill_scale;
            }
        }

        // --- layer weights: the content-matching circuit ---
        // q = normed @ Wq; query head h occupies output cols h*32..(h+1)*32.
        // All query heads mapping to KV head 0 carry the circuit (1 head in
        // MHA, 2 in GQA); their outputs are averaged back into V via Wo.
        let circuit_heads = cfg.n_heads / cfg.n_kv_heads; // query heads per kv head
        // Wq: circuit query heads read α · x[Q-subspace].
        let mut wq = Mat::zeros(dm, q_dim);
        for h in 0..circuit_heads {
            for i in 0..HEAD_DIM {
                wq.data[(Q_OFF + i) * q_dim + h * HEAD_DIM + i] = spec.alpha;
            }
        }
        // Wk: KV head 0's key = x[K-subspace] (the matching content).
        // KV heads 1 and 2 carry the Q- and V-subspace activations: they do
        // not feed the circuit (their Wv is zero) but they make the stacked
        // key vector span the full content dimensionality, like real LLM
        // keys — this is what the latent projector must budget rank for.
        let mut wk = Mat::zeros(dm, kv_dim);
        for i in 0..HEAD_DIM {
            wk.data[(K_OFF + i) * kv_dim + i] = 1.0;
            wk.data[(Q_OFF + i) * kv_dim + HEAD_DIM + i] = 1.0;
            wk.data[(V_OFF + i) * kv_dim + 2 * HEAD_DIM + i] = 1.0;
        }
        // Wv: KV head 0's value = x[V-subspace] (the payload).
        let mut wv = Mat::zeros(dm, kv_dim);
        for i in 0..HEAD_DIM {
            wv.data[(V_OFF + i) * kv_dim + i] = 1.0;
        }
        // Wo: circuit heads' outputs write back to V (averaged).
        let mut wo = Mat::zeros(q_dim, dm);
        let w_share = 1.0 / circuit_heads as f32;
        for h in 0..circuit_heads {
            for i in 0..HEAD_DIM {
                wo.data[(h * HEAD_DIM + i) * dm + (V_OFF + i)] = w_share;
            }
        }

        let layer = LayerWeights {
            wq,
            wk,
            wv,
            wo,
            w_gate: Mat::zeros(dm, 4),
            w_up: Mat::zeros(dm, 4),
            w_down: Mat::zeros(4, dm),
            norm_attn: vec![1.0; dm],
            norm_ffn: vec![1.0; dm],
        };
        let weights = Weights {
            embedding,
            layers: (0..spec.n_layers).map(|_| layer.clone()).collect(),
            norm_final: vec![1.0; dm],
        };
        RetrievalModel { cfg, weights, spec }
    }

    // ---- vocabulary codec ----

    /// Token id of needle (key, value).
    pub fn needle_token(&self, key: usize, value: usize) -> usize {
        assert!(key < self.spec.n_keys && value < self.spec.n_vals);
        key * self.spec.n_vals + value
    }

    /// Token id of the query for `key`.
    pub fn query_token(&self, key: usize) -> usize {
        assert!(key < self.spec.n_keys);
        self.spec.n_keys * self.spec.n_vals + key
    }

    /// Token id of filler `i`.
    pub fn filler_token(&self, i: usize) -> usize {
        self.spec.n_keys * self.spec.n_vals + self.spec.n_keys + (i % self.spec.n_fill)
    }

    /// Decode a needle token id back to (key, value), if it is one.
    pub fn decode_needle(&self, token: usize) -> Option<(usize, usize)> {
        if token < self.spec.n_keys * self.spec.n_vals {
            Some((token / self.spec.n_vals, token % self.spec.n_vals))
        } else {
            None
        }
    }

    /// Restrict an argmax to needle tokens of a given key (the answer set
    /// for a query, mirroring answer-span scoring in RULER).
    pub fn best_value_for_key(&self, logits: &[f32], key: usize) -> usize {
        let mut best_v = 0;
        let mut best = f32::NEG_INFINITY;
        for v in 0..self.spec.n_vals {
            let l = logits[self.needle_token(key, v)];
            if l > best {
                best = l;
                best_v = v;
            }
        }
        best_v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama::{Model, Scratch, SequenceState};
    use crate::model::BackendFactory;
    use std::sync::Arc;

    fn full_factory(cfg: &ModelConfig) -> Box<BackendFactory> {
        let shape = cfg.attn_shape();
        Box::new(move |_| {
            Box::new(crate::attention::FullAttention::new(shape))
                as Box<dyn crate::attention::AttentionBackend + Send>
        })
    }

    fn run_retrieval(rm: &RetrievalModel, ctx: &[usize], key: usize) -> usize {
        let model = Model::new(rm.cfg.clone(), Arc::new(rm.weights.clone()));
        let factory = full_factory(&rm.cfg);
        let mut state = SequenceState::new(&rm.cfg, &factory);
        let mut scratch = Scratch::new(&rm.cfg);
        let mut prompt = ctx.to_vec();
        prompt.push(rm.query_token(key));
        let logits = model.prefill(&mut state, &mut scratch, &prompt);
        rm.best_value_for_key(&logits, key)
    }

    #[test]
    fn retrieves_single_needle_through_fillers() {
        let rm = RetrievalModel::build(RetrievalSpec {
            n_keys: 16,
            n_vals: 16,
            n_fill: 32,
            max_seq: 512,
            n_layers: 4,
            ..Default::default()
        });
        let mut rng = Rng::new(201);
        let mut correct = 0;
        let trials = 20;
        for _ in 0..trials {
            let key = rng.below(16);
            let val = rng.below(16);
            let pos = rng.below(180);
            let mut ctx: Vec<usize> = (0..200).map(|i| rm.filler_token(rng.below(32) + i % 3)).collect();
            ctx[pos] = rm.needle_token(key, val);
            if run_retrieval(&rm, &ctx, key) == val {
                correct += 1;
            }
        }
        assert!(correct >= trials - 1, "retrieval accuracy {correct}/{trials}");
    }

    #[test]
    fn distractor_needles_do_not_confuse() {
        let rm = RetrievalModel::build(RetrievalSpec {
            n_keys: 16,
            n_vals: 16,
            n_fill: 32,
            max_seq: 512,
            n_layers: 4,
            ..Default::default()
        });
        let mut rng = Rng::new(203);
        let mut correct = 0;
        let trials = 20;
        for _ in 0..trials {
            let key = rng.below(16);
            let val = rng.below(16);
            let mut ctx: Vec<usize> = (0..200).map(|_| rm.filler_token(rng.below(32))).collect();
            // 4 distractor needles with different keys.
            for _ in 0..4 {
                let dk = (key + 1 + rng.below(15)) % 16;
                let dv = rng.below(16);
                let p = rng.below(200);
                ctx[p] = rm.needle_token(dk, dv);
            }
            let pos = rng.below(200);
            ctx[pos] = rm.needle_token(key, val);
            if run_retrieval(&rm, &ctx, key) == val {
                correct += 1;
            }
        }
        assert!(correct >= trials - 2, "retrieval accuracy {correct}/{trials}");
    }

    #[test]
    fn gqa_variant_retrieves() {
        let rm = RetrievalModel::build(RetrievalSpec {
            n_keys: 16,
            n_vals: 16,
            n_fill: 32,
            max_seq: 512,
            n_layers: 4,
            gqa: true,
            ..Default::default()
        });
        assert_eq!(rm.cfg.n_heads, 6);
        assert_eq!(rm.cfg.n_kv_heads, 3);
        let mut rng = Rng::new(205);
        let mut correct = 0;
        for _ in 0..10 {
            let key = rng.below(16);
            let val = rng.below(16);
            let mut ctx: Vec<usize> = (0..150).map(|_| rm.filler_token(rng.below(32))).collect();
            let pos = rng.below(150);
            ctx[pos] = rm.needle_token(key, val);
            if run_retrieval(&rm, &ctx, key) == val {
                correct += 1;
            }
        }
        assert!(correct >= 9, "GQA retrieval {correct}/10");
    }

    #[test]
    fn codec_roundtrip() {
        let rm = RetrievalModel::build(RetrievalSpec {
            n_keys: 8,
            n_vals: 8,
            n_fill: 16,
            max_seq: 64,
            n_layers: 3,
            ..Default::default()
        });
        assert_eq!(rm.decode_needle(rm.needle_token(3, 5)), Some((3, 5)));
        assert_eq!(rm.decode_needle(rm.query_token(3)), None);
        assert!(rm.filler_token(99) < rm.cfg.vocab);
    }
}

//! Figure 1(b): applying RoPE rotates the principal axes of the key cloud
//! and scatters the points (variance amplification). This module generates
//! the figure's data: a key set's leading principal direction and spectrum
//! before and after position-dependent rotation.

use crate::linalg::{eig_symmetric, CovAccumulator};
use crate::rope::RopeTable;
use crate::util::rng::Rng;

/// Output of the PCA-rotation demo.
#[derive(Clone, Debug)]
pub struct PcaRopeReport {
    /// Leading eigenvalue pre/post RoPE.
    pub lead_eig_pre: f32,
    pub lead_eig_post: f32,
    /// Ratio λ1/λ2 pre/post (axis dominance; drops when RoPE scatters).
    pub anisotropy_pre: f32,
    pub anisotropy_post: f32,
    /// |cos| of the angle between pre/post leading principal directions.
    pub principal_cos: f32,
    /// Full spectra.
    pub spectrum_pre: Vec<f32>,
    pub spectrum_post: Vec<f32>,
}

/// Build an anisotropic 2-plane key family embedded in `head_dim`, rotate
/// copies at positions 0..s, and compare PCA before/after — the Figure 1(b)
/// experiment.
pub fn pca_rope_demo(head_dim: usize, s: usize, base: f32, seed: u64) -> PcaRopeReport {
    let mut rng = Rng::new(seed);
    let rope = RopeTable::new(head_dim, s.max(2), base);
    // Key distribution concentrated along one direction (plus small noise):
    // mimics the pre-RoPE keys' dominant principal component.
    let dir = {
        let mut d = rng.normal_vec(head_dim, 1.0);
        let n = d.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in d.iter_mut() {
            *x /= n;
        }
        d
    };
    let mut pre = CovAccumulator::new(head_dim);
    let mut post = CovAccumulator::new(head_dim);
    let mut k = vec![0.0f32; head_dim];
    for pos in 0..s {
        let c = rng.normal_f32() * 2.0 + 3.0; // offset cloud, dominant axis
        for (i, x) in k.iter_mut().enumerate() {
            *x = c * dir[i] + rng.normal_f32() * 0.15;
        }
        pre.add_row(&k);
        let mut kr = k.clone();
        rope.apply(&mut kr, pos);
        post.add_row(&kr);
    }
    let e_pre = eig_symmetric(&pre.finish(true), 50, 1e-9);
    let e_post = eig_symmetric(&post.finish(true), 50, 1e-9);
    // Leading principal directions.
    let d = head_dim;
    let v_pre: Vec<f32> = (0..d).map(|i| e_pre.vectors.data[i * d]).collect();
    let v_post: Vec<f32> = (0..d).map(|i| e_post.vectors.data[i * d]).collect();
    let cosv = crate::util::stats::cosine(&v_pre, &v_post).abs();
    PcaRopeReport {
        lead_eig_pre: e_pre.values[0],
        lead_eig_post: e_post.values[0],
        anisotropy_pre: e_pre.values[0] / e_pre.values[1].max(1e-9),
        anisotropy_post: e_post.values[0] / e_post.values[1].max(1e-9),
        principal_cos: cosv as f32,
        spectrum_pre: e_pre.values,
        spectrum_post: e_post.values,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rank_at_energy;

    #[test]
    fn rope_scatters_and_rotates() {
        let r = pca_rope_demo(16, 512, 10_000.0, 42);
        // Paper's Figure 1(b): points scatter onto two main components and
        // the principal direction rotates away.
        assert!(
            r.anisotropy_post < r.anisotropy_pre,
            "anisotropy should drop: {} -> {}",
            r.anisotropy_pre,
            r.anisotropy_post
        );
        assert!(r.principal_cos < 0.9, "principal axis barely moved: {}", r.principal_cos);
    }

    #[test]
    fn rope_increases_effective_rank() {
        let r = pca_rope_demo(32, 1024, 10_000.0, 43);
        let pre = rank_at_energy(&r.spectrum_pre, 90.0);
        let post = rank_at_energy(&r.spectrum_post, 90.0);
        assert!(post > pre, "rank90 pre {pre} post {post}");
    }
}

//! Figure 4 / Appendix A: eigenvalue spectra of the key covariance before
//! and after RoPE, and the Rank_l(90) metric per layer.

use crate::linalg::{eig_symmetric, rank_at_energy, CovAccumulator};
use crate::rope::RopeTable;

/// Per-layer rank analysis output.
#[derive(Clone, Debug)]
pub struct RankReport {
    pub layer: usize,
    pub rank90_pre: usize,
    pub rank90_post: usize,
    pub spectrum_pre: Vec<f32>,
    pub spectrum_post: Vec<f32>,
}

/// Analyze one layer's calibration keys ((n, kv_dim) row-major, positions
/// assumed 0..n within each stream of length `stream_len`).
pub fn rank_analysis(
    layer: usize,
    keys: &[f32],
    kv_dim: usize,
    head_dim: usize,
    stream_len: usize,
    rope_base: f32,
) -> RankReport {
    assert_eq!(keys.len() % kv_dim, 0);
    let n = keys.len() / kv_dim;
    let rope = RopeTable::new(head_dim, stream_len.max(1), rope_base);
    let mut pre = CovAccumulator::new(kv_dim);
    let mut post = CovAccumulator::new(kv_dim);
    let mut kr = vec![0.0f32; kv_dim];
    for j in 0..n {
        let row = &keys[j * kv_dim..(j + 1) * kv_dim];
        pre.add_row(row);
        kr.copy_from_slice(row);
        rope.apply_multihead(&mut kr, j % stream_len);
        post.add_row(&kr);
    }
    let e_pre = eig_symmetric(&pre.finish(true), 50, 1e-9);
    let e_post = eig_symmetric(&post.finish(true), 50, 1e-9);
    RankReport {
        layer,
        rank90_pre: rank_at_energy(&e_pre.values, 90.0),
        rank90_post: rank_at_energy(&e_post.values, 90.0),
        spectrum_pre: e_pre.values,
        spectrum_post: e_post.values,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn low_rank_keys_gain_rank_after_rope() {
        // Keys in a 3-D subspace of R^16; RoPE mixes position into them.
        let mut rng = Rng::new(701);
        let kv = 16;
        let basis: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(kv, 1.0)).collect();
        let n = 512;
        let mut keys = vec![0.0f32; n * kv];
        for j in 0..n {
            for b in &basis {
                crate::tensor::ops::axpy(
                    rng.normal_f32() + 1.0,
                    b,
                    &mut keys[j * kv..(j + 1) * kv],
                );
            }
        }
        let rep = rank_analysis(0, &keys, kv, 8, n, 10_000.0);
        assert!(rep.rank90_pre <= 3, "pre rank {}", rep.rank90_pre);
        assert!(
            rep.rank90_post > rep.rank90_pre,
            "post {} should exceed pre {}",
            rep.rank90_post,
            rep.rank90_pre
        );
    }

    #[test]
    fn spectra_are_descending() {
        let mut rng = Rng::new(703);
        let keys = rng.normal_vec(128 * 8, 1.0);
        let rep = rank_analysis(1, &keys, 8, 4, 128, 10_000.0);
        for w in rep.spectrum_pre.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
        for w in rep.spectrum_post.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
    }
}

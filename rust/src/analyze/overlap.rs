//! Figure 2: the overlap score (OS) — fraction of the full-attention mass
//! captured by the top-N_c tokens ranked in the pre-RoPE latent space.
//!
//! OS = Σ_{i∈C} p_i / Σ_i p_i where p is the exact attention distribution
//! and C the top-N_c index set by latent score (§3.2). The paper finds
//! OS > 90% for layers 2–29 and < 50% for layers 0–1 on LLaMA/Mistral,
//! motivating the dense-layer skip list.

use crate::lowrank::Projector;
use crate::rope::RopeTable;
use crate::tensor::top_k_indices;

/// Overlap score of one (query, key-set) pair.
///
/// `q`, `keys` are pre-RoPE (kv_dim / (s, kv_dim)); the exact distribution
/// is computed post-RoPE at `pos_q` with per-token positions 0..s, single
/// pooled head (head_dim = kv_dim is acceptable because OS is a property of
/// score *ranking*, which the multi-head split preserves on average).
pub fn overlap_score(
    proj: &Projector,
    rope: &RopeTable,
    head_dim: usize,
    q: &[f32],
    keys: &[f32],
    n_c: usize,
    r_star: usize,
) -> f64 {
    let kv_dim = proj.dim;
    assert_eq!(q.len(), kv_dim);
    assert_eq!(keys.len() % kv_dim, 0);
    let s = keys.len() / kv_dim;
    assert!(s > 0);
    let pos_q = s - 1;

    // Exact post-RoPE attention distribution (pooled single-head softmax
    // per head then averaged — equivalent to the multi-head mean mass).
    let n_heads = kv_dim / head_dim;
    let mut qr = q.to_vec();
    rope.apply_multihead(&mut qr, pos_q);
    let mut logits = vec![0.0f32; s];
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut krot = vec![0.0f32; kv_dim];
    for j in 0..s {
        krot.copy_from_slice(&keys[j * kv_dim..(j + 1) * kv_dim]);
        rope.apply_multihead(&mut krot, j);
        // Mean over heads of per-head scores.
        let mut sum = 0.0f32;
        for h in 0..n_heads {
            sum += crate::tensor::ops::dot(
                &qr[h * head_dim..(h + 1) * head_dim],
                &krot[h * head_dim..(h + 1) * head_dim],
            );
        }
        logits[j] = sum * scale / n_heads as f32;
    }
    let mut probs = logits;
    crate::tensor::ops::softmax(&mut probs);

    // Latent-space ranking (pre-RoPE, r* dims).
    let mut qlat = vec![0.0f32; proj.rank];
    proj.project(q, &mut qlat);
    let mut klat = vec![0.0f32; proj.rank];
    let mut scores = vec![0.0f32; s];
    for j in 0..s {
        proj.project(&keys[j * kv_dim..(j + 1) * kv_dim], &mut klat);
        scores[j] = crate::tensor::ops::dot(&qlat[..r_star], &klat[..r_star]);
    }
    let top = top_k_indices(&scores, n_c.min(s));
    top.iter().map(|&i| probs[i] as f64).sum::<f64>()
}

/// Mean overlap score per layer given per-layer calibration keys — drives
/// the Figure-2 reproduction (`sals analyze overlap`).
pub fn overlap_by_layer(
    projs: &[Projector],
    layers_keys: &[Vec<f32>],
    head_dim: usize,
    rope: &RopeTable,
    n_c: usize,
    r_star_frac: f64,
    queries_per_layer: usize,
    seed: u64,
) -> Vec<f64> {
    use crate::util::rng::Rng;
    assert_eq!(projs.len(), layers_keys.len());
    let mut out = Vec::with_capacity(projs.len());
    for (proj, keys) in projs.iter().zip(layers_keys) {
        let kv_dim = proj.dim;
        let s = keys.len() / kv_dim;
        let r_star = ((proj.rank as f64 * r_star_frac) as usize).max(1);
        let mut rng = Rng::new(seed ^ proj.rank as u64 ^ s as u64);
        let mut acc = 0.0;
        for _ in 0..queries_per_layer {
            // Queries drawn from the key distribution (same subspace).
            let j = rng.below(s);
            let mut q = keys[j * kv_dim..(j + 1) * kv_dim].to_vec();
            for x in q.iter_mut() {
                *x += rng.normal_f32() * 0.1;
            }
            acc += overlap_score(proj, rope, head_dim, &q, keys, n_c, r_star);
        }
        out.push(acc / queries_per_layer as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::Calibrator;
    use crate::util::rng::Rng;

    fn low_rank_keys(s: usize, kv: usize, true_rank: usize, rng: &mut Rng) -> Vec<f32> {
        let basis: Vec<Vec<f32>> = (0..true_rank).map(|_| rng.normal_vec(kv, 1.0)).collect();
        let mut keys = vec![0.0f32; s * kv];
        for j in 0..s {
            for b in &basis {
                crate::tensor::ops::axpy(rng.normal_f32(), b, &mut keys[j * kv..(j + 1) * kv]);
            }
        }
        keys
    }

    #[test]
    fn full_budget_overlap_is_one() {
        let mut rng = Rng::new(601);
        let kv = 16;
        let keys = low_rank_keys(40, kv, 4, &mut rng);
        let mut cal = Calibrator::new(kv);
        cal.add_keys(&keys);
        let proj = cal.fit(8).unwrap();
        let rope = RopeTable::new(8, 64, 10_000.0);
        let q = keys[..kv].to_vec();
        let os = overlap_score(&proj, &rope, 8, &q, &keys, 40, 8);
        assert!((os - 1.0).abs() < 1e-6, "{os}");
    }

    #[test]
    fn overlap_decreases_with_smaller_budget() {
        let mut rng = Rng::new(603);
        let kv = 16;
        let keys = low_rank_keys(60, kv, 4, &mut rng);
        let mut cal = Calibrator::new(kv);
        cal.add_keys(&keys);
        let proj = cal.fit(8).unwrap();
        let rope = RopeTable::new(8, 64, 10_000.0);
        let q = keys[..kv].to_vec();
        let os_big = overlap_score(&proj, &rope, 8, &q, &keys, 30, 4);
        let os_small = overlap_score(&proj, &rope, 8, &q, &keys, 2, 4);
        assert!(os_big >= os_small, "{os_big} vs {os_small}");
        assert!(os_big > 0.5);
    }

    #[test]
    fn good_latent_space_high_overlap() {
        // Keys in a genuine low-rank subspace: latent ranking ≈ exact
        // ranking -> OS near 1 with a quarter budget.
        let mut rng = Rng::new(605);
        let kv = 32;
        let keys = low_rank_keys(80, kv, 4, &mut rng);
        let mut cal = Calibrator::new(kv);
        cal.add_keys(&keys);
        let proj = cal.fit(8).unwrap();
        let rope = RopeTable::new(16, 128, 10_000.0);
        let mut acc = 0.0;
        for t in 0..5 {
            let q = keys[t * kv..(t + 1) * kv].to_vec();
            acc += overlap_score(&proj, &rope, 16, &q, &keys, 20, 4);
        }
        assert!(acc / 5.0 > 0.8, "mean OS {}", acc / 5.0);
    }
}

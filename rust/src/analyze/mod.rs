//! Analyses behind the paper's figures: RoPE's effect on key geometry
//! (Figure 1b, Figure 4) and the latent-space overlap score (Figure 2).

pub mod overlap;
pub mod pca_rope;
pub mod rank;

pub use overlap::{overlap_score, overlap_by_layer};
pub use pca_rope::pca_rope_demo;
pub use rank::{rank_analysis, RankReport};

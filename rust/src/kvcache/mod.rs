//! Paged KV-cache pool + shared-prefix index — the vLLM-style block
//! manager that gives the coordinator admission control, backpressure,
//! and prefix-reuse accounting over latent-cache memory.
//!
//! Backends own their storage; the pool is the *allocator of record*: every
//! sequence must reserve pages (fixed-size byte blocks) before its caches
//! may grow. When the pool is exhausted, the scheduler stops admitting new
//! sequences and queues them (backpressure), exactly like vLLM's block
//! manager refusing block allocation. Because SALS caches are `d_r`-times
//! smaller, the same pool admits proportionally more concurrent sequences —
//! the mechanism behind the Table-7 throughput gains at long contexts.
//!
//! The pool is a *ledger*, deliberately ignorant of what the bytes mean.
//! Who reserves how much is the engine's policy, and it uses the pool in
//! three modes (see the footprint contract in `crate::attention`):
//!
//! * **Admission reservation** — at admit time the engine reserves the
//!   factory's predicted footprint ([`crate::model::SequenceFootprint`])
//!   for the request's whole decode horizon, so one admission pass cannot
//!   promise the same free pages to several requests.
//! * **Growth accounting** — each step every running sequence re-reserves
//!   `max(measured kv_bytes() − shared_prefix_bytes(), admission
//!   reservation)`; the estimate is the floor, the live meter only ever
//!   raises it. Bytes held *by reference* to a shared prefix are
//!   subtracted because the shared ledger already charges them once.
//! * **Shared-pages mode** — pages backing a published prefix
//!   ([`PagePool::publish_shared`]) are carved out of the free set once
//!   and tracked per [`SharedId`] with a refcount: adopters
//!   [`PagePool::retain_shared`] / [`PagePool::release_shared`] instead
//!   of reserving private copies, so N sequences sharing one prompt
//!   prefix charge it once. Unreferenced holdings stay resident as cache
//!   and are LRU-evicted whenever a reservation or publication runs out
//!   of free pages ([`PagePool::take_evicted`] reports which, so the
//!   prefix index stays in sync). A sequence that must privatize its
//!   share (divergence that copies the data) runs
//!   [`PagePool::cow_split`], which atomically swaps its reference for an
//!   equal private holding.
//!
//! The ledger invariant across all three modes is
//! `free + Σprivate + Σshared == total` ([`PagePool::check_invariants`]).
//!
//! [`PrefixCache`] is the content-addressed index over the shared mode:
//! prompt prefixes are keyed by a rolling FNV-1a hash of their token
//! chunks at a fixed granularity (the engine uses
//! [`crate::model::Model::PREFILL_CHUNK`]), with stored-token
//! verification so a hash collision can never adopt the wrong prefix.

use crate::util::{Error, Result};
use std::collections::HashMap;

/// Sequence identifier used by the pool and coordinator.
pub type SeqId = u64;

/// Identity of one published shared-prefix holding in the pool's ledger.
pub type SharedId = u64;

/// One refcounted shared holding: pages charged once, shared by every
/// sequence holding a reference.
#[derive(Debug)]
struct SharedEntry {
    pages: usize,
    refs: usize,
    /// LRU clock stamp of the last publish/retain/release touch —
    /// unreferenced entries are evicted oldest-stamp-first.
    stamp: u64,
}

/// Fixed-size-page memory pool with per-sequence and shared-prefix
/// accounting.
#[derive(Debug)]
pub struct PagePool {
    /// Bytes per page.
    pub page_bytes: usize,
    /// Total pages in the pool.
    pub total_pages: usize,
    free_pages: usize,
    /// Private pages held per sequence.
    held: HashMap<SeqId, usize>,
    /// Shared-prefix holdings (pages charged once across all referents).
    shared: HashMap<SharedId, SharedEntry>,
    next_shared: SharedId,
    clock: u64,
    /// Shared ids evicted since the last [`PagePool::take_evicted`] —
    /// the prefix index drains this to drop stale entries.
    evicted: Vec<SharedId>,
    /// Peak utilization (pages), for reports.
    peak_used: usize,
}

impl PagePool {
    pub fn new(page_bytes: usize, total_pages: usize) -> PagePool {
        assert!(page_bytes > 0 && total_pages > 0);
        PagePool {
            page_bytes,
            total_pages,
            free_pages: total_pages,
            held: HashMap::new(),
            shared: HashMap::new(),
            next_shared: 0,
            clock: 0,
            evicted: Vec::new(),
            peak_used: 0,
        }
    }

    /// Pool sized for a byte budget.
    pub fn with_budget(page_bytes: usize, budget_bytes: usize) -> PagePool {
        PagePool::new(page_bytes, (budget_bytes / page_bytes).max(1))
    }

    pub fn free_pages(&self) -> usize {
        self.free_pages
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free_pages
    }

    pub fn peak_used_pages(&self) -> usize {
        self.peak_used
    }

    /// Pages needed to hold `bytes`.
    pub fn pages_for(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.page_bytes)
    }

    /// Private pages currently held by a sequence.
    pub fn held_by(&self, seq: SeqId) -> usize {
        self.held.get(&seq).copied().unwrap_or(0)
    }

    /// Pages currently charged to shared-prefix holdings (referenced or
    /// cached-unreferenced).
    pub fn shared_pages(&self) -> usize {
        self.shared.values().map(|e| e.pages).sum()
    }

    /// Reference count of a shared holding (None once evicted/dropped).
    pub fn shared_refs(&self, id: SharedId) -> Option<usize> {
        self.shared.get(&id).map(|e| e.refs)
    }

    /// Pages reclaimable by evicting unreferenced shared holdings.
    fn evictable_pages(&self) -> usize {
        self.shared.values().filter(|e| e.refs == 0).map(|e| e.pages).sum()
    }

    /// Evict unreferenced shared holdings (LRU stamp order) until
    /// `need` pages are free or nothing evictable remains. Evicted ids
    /// accumulate for [`PagePool::take_evicted`].
    fn evict_for(&mut self, need: usize) {
        while self.free_pages < need {
            let victim = self
                .shared
                .iter()
                .filter(|(_, e)| e.refs == 0)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            let e = self.shared.remove(&id).unwrap();
            self.free_pages += e.pages;
            self.evicted.push(id);
        }
    }

    /// Drain the list of shared ids evicted since the last call — the
    /// prefix index uses this to invalidate its entries.
    pub fn take_evicted(&mut self) -> Vec<SharedId> {
        std::mem::take(&mut self.evicted)
    }

    /// Can `seq` grow to `target_bytes` without exceeding the pool?
    /// Counts unreferenced shared holdings as free — [`PagePool::reserve`]
    /// evicts them on demand, and the two must agree exactly (the
    /// admission path is check-then-act).
    pub fn can_grow_to(&self, seq: SeqId, target_bytes: usize) -> bool {
        let need = self.pages_for(target_bytes);
        let have = self.held_by(seq);
        need <= have || need - have <= self.free_pages + self.evictable_pages()
    }

    /// Grow (or shrink) a sequence's private reservation to cover
    /// `target_bytes`, evicting unreferenced shared holdings under
    /// pressure. Fails with `Error::Coordinator` when the pool is
    /// exhausted — callers translate that into scheduling backpressure.
    pub fn reserve(&mut self, seq: SeqId, target_bytes: usize) -> Result<()> {
        let need = self.pages_for(target_bytes);
        let have = self.held_by(seq);
        if need > have {
            let grow = need - have;
            self.evict_for(grow);
            if grow > self.free_pages {
                return Err(Error::Coordinator(format!(
                    "pool exhausted: seq {seq} needs {grow} pages, {} free",
                    self.free_pages
                )));
            }
            self.free_pages -= grow;
        } else {
            self.free_pages += have - need;
        }
        if need == 0 {
            self.held.remove(&seq);
        } else {
            self.held.insert(seq, need);
        }
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Release every private page a finished sequence holds. Shared
    /// references are released separately ([`PagePool::release_shared`])
    /// by whoever tracked the adoption.
    pub fn release(&mut self, seq: SeqId) {
        if let Some(pages) = self.held.remove(&seq) {
            self.free_pages += pages;
        }
    }

    /// Publish `bytes` as a new shared holding: pages leave the free set
    /// once and stay charged until the holding is evicted. Starts with
    /// zero references (the publisher keeps its own private reservation;
    /// only *adopters* retain), so an unadopted publication is immediately
    /// reclaimable under pressure. Evicts older unreferenced holdings if
    /// the free set is short.
    pub fn publish_shared(&mut self, bytes: usize) -> Result<SharedId> {
        let pages = self.pages_for(bytes);
        self.evict_for(pages);
        if pages > self.free_pages {
            return Err(Error::Coordinator(format!(
                "pool exhausted: shared publication needs {pages} pages, {} free",
                self.free_pages
            )));
        }
        self.free_pages -= pages;
        let id = self.next_shared;
        self.next_shared += 1;
        self.clock += 1;
        self.shared.insert(id, SharedEntry { pages, refs: 0, stamp: self.clock });
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(id)
    }

    /// Take a reference on a shared holding (an adoption). False if the
    /// holding was already evicted — the caller must fall back to a cold
    /// prefill with private pages.
    pub fn retain_shared(&mut self, id: SharedId) -> bool {
        self.clock += 1;
        let clock = self.clock;
        match self.shared.get_mut(&id) {
            Some(e) => {
                e.refs += 1;
                e.stamp = clock;
                true
            }
            None => false,
        }
    }

    /// Drop one reference (adopter finished or diverged). The holding
    /// stays resident as reusable cache until pressure evicts it.
    pub fn release_shared(&mut self, id: SharedId) {
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.shared.get_mut(&id) {
            debug_assert!(e.refs > 0, "release_shared on unreferenced holding {id}");
            e.refs = e.refs.saturating_sub(1);
            e.stamp = clock;
        }
    }

    /// Copy-on-write split: `seq` stops referencing holding `id` and
    /// instead holds the same number of pages privately (the caller
    /// performs the matching data copy). Atomic on the ledger: on error
    /// (not enough pages for the private copy even after eviction, or the
    /// holding is gone/unreferenced) nothing changes.
    pub fn cow_split(&mut self, seq: SeqId, id: SharedId) -> Result<()> {
        let pages = match self.shared.get(&id) {
            Some(e) if e.refs > 0 => e.pages,
            _ => {
                return Err(Error::Coordinator(format!(
                    "cow_split: shared holding {id} missing or unreferenced"
                )))
            }
        };
        self.evict_for(pages);
        if pages > self.free_pages {
            return Err(Error::Coordinator(format!(
                "pool exhausted: cow_split needs {pages} pages, {} free",
                self.free_pages
            )));
        }
        self.free_pages -= pages;
        *self.held.entry(seq).or_insert(0) += pages;
        self.release_shared(id);
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Invariant check: free + Σprivate + Σshared == total. Used by
    /// property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let held: usize = self.held.values().sum();
        let shared = self.shared_pages();
        if held + shared + self.free_pages != self.total_pages {
            return Err(Error::Coordinator(format!(
                "pool accounting broken: held {held} + shared {shared} + free {} != total {}",
                self.free_pages, self.total_pages
            )));
        }
        Ok(())
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Extend an FNV-1a state over a token slice (each token as 8 LE bytes).
/// Sequential over bytes, so hashing a prefix chunk-by-chunk equals
/// hashing it in one pass — the rolling property `lookup_longest` uses.
fn fnv_extend(mut h: u64, tokens: &[usize]) -> u64 {
    for &t in tokens {
        for b in (t as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Content hash of a token prefix — the exact key [`PrefixCache`] files
/// entries under. Public so layers above one engine (the cluster
/// coordinator's replica-placement index) can speak the same
/// content-keyed language without holding a `PrefixCache` of their own.
pub fn prefix_hash(tokens: &[usize]) -> u64 {
    fnv_extend(FNV_OFFSET, tokens)
}

/// `(prefix_len, hash)` of every complete chunk-aligned prefix of
/// `tokens`, longest last — one rolling pass, each hash identical to
/// [`prefix_hash`] of that prefix. The cluster coordinator walks this
/// against its publication index to find the replica most likely to hold
/// a request's warm prefix.
pub fn prefix_hashes(tokens: &[usize], chunk: usize) -> Vec<(usize, u64)> {
    assert!(chunk > 0);
    let mut h = FNV_OFFSET;
    let m = tokens.len() / chunk;
    let mut out = Vec::with_capacity(m);
    for k in 1..=m {
        let hi = k * chunk;
        h = fnv_extend(h, &tokens[(k - 1) * chunk..hi]);
        out.push((hi, h));
    }
    out
}

struct PrefixEntry<T> {
    /// The exact prefix tokens — verified on lookup so a hash collision
    /// can never adopt the wrong prefix.
    tokens: Vec<usize>,
    shared_id: SharedId,
    value: T,
}

/// Content-addressed index of published prompt prefixes: chunk-aligned
/// prefixes keyed by rolling FNV hash, each carrying the pool's
/// [`SharedId`] for its pages and an arbitrary payload `T` (the engine
/// stores a `SequenceSnapshot`). The cache itself holds no pages — the
/// pool's shared ledger does; when the pool evicts a holding, the engine
/// drains [`PagePool::take_evicted`] and calls
/// [`PrefixCache::remove_shared`] to keep the index honest.
pub struct PrefixCache<T> {
    chunk: usize,
    entries: HashMap<u64, PrefixEntry<T>>,
}

impl<T> PrefixCache<T> {
    /// `chunk` is the prefix granularity in tokens (the engine passes its
    /// prefill chunk size so published boundaries match prefill steps).
    pub fn new(chunk: usize) -> PrefixCache<T> {
        assert!(chunk > 0);
        PrefixCache { chunk, entries: HashMap::new() }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest chunk-aligned prefix of `tokens` with a published entry:
    /// `(prefix_len, shared_id, &payload)`. One rolling-hash pass over
    /// the complete chunks; every hit is verified against the stored
    /// tokens before it can win.
    pub fn lookup_longest(&self, tokens: &[usize]) -> Option<(usize, SharedId, &T)> {
        let mut h = FNV_OFFSET;
        let mut best = None;
        let m = tokens.len() / self.chunk;
        for k in 1..=m {
            let hi = k * self.chunk;
            h = fnv_extend(h, &tokens[(k - 1) * self.chunk..hi]);
            if let Some(e) = self.entries.get(&h) {
                if e.tokens.len() == hi && e.tokens == tokens[..hi] {
                    best = Some((hi, e.shared_id, &e.value));
                }
            }
        }
        best
    }

    /// Is this exact chunk-aligned prefix already published?
    pub fn contains(&self, prefix: &[usize]) -> bool {
        if prefix.is_empty() || prefix.len() % self.chunk != 0 {
            return false;
        }
        let h = fnv_extend(FNV_OFFSET, prefix);
        self.entries.get(&h).is_some_and(|e| e.tokens == prefix)
    }

    /// Publish a chunk-aligned prefix. False (and no change) if an entry
    /// already occupies this hash slot — the existing publication wins;
    /// the caller should drop its redundant pool holding.
    pub fn insert(&mut self, prefix: &[usize], shared_id: SharedId, value: T) -> bool {
        assert!(
            !prefix.is_empty() && prefix.len() % self.chunk == 0,
            "prefix cache entries must be whole chunks ({} tokens given, chunk {})",
            prefix.len(),
            self.chunk
        );
        let h = fnv_extend(FNV_OFFSET, prefix);
        if self.entries.contains_key(&h) {
            return false;
        }
        self.entries.insert(h, PrefixEntry { tokens: prefix.to_vec(), shared_id, value });
        true
    }

    /// Drop every entry backed by an evicted shared holding; returns the
    /// content hashes of the removed entries so the layer above (the
    /// cluster coordinator's placement index) can retire the same keys.
    pub fn remove_shared(&mut self, id: SharedId) -> Vec<u64> {
        let mut removed = Vec::new();
        self.entries.retain(|&h, e| {
            let keep = e.shared_id != id;
            if !keep {
                removed.push(h);
            }
            keep
        });
        removed
    }

    /// Shared ids of all live entries (engine shutdown / tests).
    pub fn shared_ids(&self) -> Vec<SharedId> {
        self.entries.values().map(|e| e.shared_id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn reserve_and_release() {
        let mut p = PagePool::new(1024, 10);
        p.reserve(1, 3000).unwrap(); // 3 pages
        assert_eq!(p.used_pages(), 3);
        p.reserve(2, 7 * 1024).unwrap();
        assert_eq!(p.free_pages(), 0);
        assert!(p.reserve(3, 1).is_err());
        p.release(1);
        assert_eq!(p.free_pages(), 3);
        p.reserve(3, 1).unwrap();
        p.check_invariants().unwrap();
    }

    #[test]
    fn grow_and_shrink_same_seq() {
        let mut p = PagePool::new(100, 10);
        p.reserve(1, 250).unwrap(); // 3 pages
        p.reserve(1, 950).unwrap(); // 10 pages
        assert_eq!(p.free_pages(), 0);
        p.reserve(1, 100).unwrap(); // shrink to 1
        assert_eq!(p.free_pages(), 9);
        p.reserve(1, 0).unwrap(); // full shrink removes entry
        assert_eq!(p.held_by(1), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn can_grow_to_is_consistent_with_reserve() {
        let mut p = PagePool::new(10, 5);
        p.reserve(1, 30).unwrap();
        assert!(p.can_grow_to(1, 50));
        assert!(!p.can_grow_to(2, 30));
        assert!(p.can_grow_to(2, 20));
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut p = PagePool::new(10, 8);
        p.reserve(1, 60).unwrap();
        p.release(1);
        assert_eq!(p.used_pages(), 0);
        assert_eq!(p.peak_used_pages(), 6);
    }

    #[test]
    fn shared_publish_retain_release_accounting() {
        let mut p = PagePool::new(10, 10);
        let id = p.publish_shared(35).unwrap(); // 4 pages
        assert_eq!(p.shared_pages(), 4);
        assert_eq!(p.free_pages(), 6);
        assert_eq!(p.shared_refs(id), Some(0));
        assert!(p.retain_shared(id));
        assert!(p.retain_shared(id));
        assert_eq!(p.shared_refs(id), Some(2));
        p.release_shared(id);
        assert_eq!(p.shared_refs(id), Some(1));
        p.check_invariants().unwrap();
        // Referenced holdings are NOT evictable: a reservation larger
        // than free-but-smaller-than-free+shared must fail.
        assert!(!p.can_grow_to(7, 10 * 10));
        assert!(p.reserve(7, 10 * 10).is_err());
        assert_eq!(p.shared_refs(id), Some(1), "referenced holding survived pressure");
        p.check_invariants().unwrap();
    }

    #[test]
    fn unreferenced_holdings_evict_lru_under_pressure() {
        let mut p = PagePool::new(10, 10);
        let a = p.publish_shared(30).unwrap(); // 3 pages, oldest
        let b = p.publish_shared(30).unwrap(); // 3 pages
        assert_eq!(p.free_pages(), 4);
        // Touch `a` so `b` becomes LRU.
        assert!(p.retain_shared(a));
        p.release_shared(a);
        // 6 pages needed: free 4 + evicting LRU `b` covers it.
        assert!(p.can_grow_to(1, 60));
        p.reserve(1, 60).unwrap();
        assert_eq!(p.take_evicted(), vec![b]);
        assert_eq!(p.shared_refs(b), None);
        assert_eq!(p.shared_refs(a), Some(0), "recently-touched holding survives");
        p.check_invariants().unwrap();
    }

    #[test]
    fn cow_split_swaps_reference_for_private_pages() {
        let mut p = PagePool::new(10, 10);
        let id = p.publish_shared(30).unwrap(); // 3 pages
        assert!(p.retain_shared(id));
        p.reserve(1, 20).unwrap(); // 2 private pages
        p.cow_split(1, id).unwrap();
        assert_eq!(p.held_by(1), 5, "private holding absorbed the copied pages");
        assert_eq!(p.shared_refs(id), Some(0));
        p.check_invariants().unwrap();
        // Unreferenced after the split: reclaimable under pressure.
        p.reserve(2, 50).unwrap();
        assert_eq!(p.take_evicted(), vec![id]);
        p.check_invariants().unwrap();
        // cow_split on a gone/unreferenced holding is an error, no change.
        assert!(p.cow_split(1, id).is_err());
        p.check_invariants().unwrap();
    }

    #[test]
    fn prefix_cache_longest_match_and_collision_verification() {
        let mut c: PrefixCache<&'static str> = PrefixCache::new(4);
        let toks: Vec<usize> = (0..12).collect();
        let mut pool = PagePool::new(10, 20);
        let id4 = pool.publish_shared(40).unwrap();
        let id8 = pool.publish_shared(40).unwrap();
        assert!(c.insert(&toks[..4], id4, "four"));
        assert!(c.insert(&toks[..8], id8, "eight"));
        assert!(!c.insert(&toks[..4], id4, "dup"), "re-publication is refused");
        // Longest complete-chunk prefix wins; trailing partial chunk ignored.
        let (n, id, v) = c.lookup_longest(&toks[..11]).unwrap();
        assert_eq!((n, id, *v), (8, id8, "eight"));
        // A prompt diverging in the second chunk falls back to the first.
        let mut div = toks.clone();
        div[5] = 99;
        let (n, id, v) = c.lookup_longest(&div).unwrap();
        assert_eq!((n, id, *v), (4, id4, "four"));
        // Unrelated prompt: no hit (hash might alias, tokens never do).
        assert!(c.lookup_longest(&[7usize; 12]).is_none());
        assert!(c.contains(&toks[..8]));
        assert!(!c.contains(&toks[..7]), "non-chunk-aligned prefixes are never published");
        // Eviction sync: dropping id8's entry leaves only the short prefix,
        // and the removal reports the retired content hash.
        assert_eq!(c.remove_shared(id8), vec![prefix_hash(&toks[..8])]);
        let (n, _, _) = c.lookup_longest(&toks).unwrap();
        assert_eq!(n, 4);
    }

    #[test]
    fn prefix_hashes_match_cache_keys() {
        // The public rolling enumeration must produce exactly the hashes
        // the cache files entries under, so a coordinator-side index and
        // per-engine caches agree on every chunk-aligned prefix.
        let toks: Vec<usize> = (10..31).collect();
        let hs = prefix_hashes(&toks, 4);
        assert_eq!(hs.len(), 5, "21 tokens / chunk 4 = 5 complete chunks");
        for &(n, h) in &hs {
            assert_eq!(h, prefix_hash(&toks[..n]), "prefix of {n}");
        }
        let mut c: PrefixCache<()> = PrefixCache::new(4);
        assert!(c.insert(&toks[..8], 1, ()));
        let (n, _, _) = c.lookup_longest(&toks).unwrap();
        assert_eq!(n, 8);
        assert_eq!(hs[1], (8, prefix_hash(&toks[..8])));
    }

    #[test]
    fn property_random_ops_preserve_accounting() {
        // Random interleavings of the engine's usage patterns — admission
        // reservation (check-then-act must agree), floored growth
        // re-reservation, release, and the shared-prefix ops
        // (publish/retain/release-ref/cow-split with LRU eviction) — never
        // break accounting, never exceed capacity, never leak or
        // double-free a refcount.
        prop::check(
            "pagepool-accounting",
            200,
            |rng: &mut Rng| {
                // encode an op sequence as raw numbers
                let n_ops = rng.range(1, 40);
                (0..n_ops * 3).map(|_| rng.below(1000)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut p = PagePool::new(16, 32);
                // Live shared ids and the references the "engine" holds on
                // them ((id, refs) mirrors what the pool should report).
                let mut ids: Vec<(SharedId, usize)> = Vec::new();
                for chunk in ops.chunks_exact(3) {
                    let (seq, kind, amt) = (chunk[0] % 6, chunk[1] % 8, chunk[2]);
                    let seq = seq as SeqId;
                    match kind {
                        0 => {
                            let _ = p.reserve(seq, amt);
                        }
                        1 => {
                            // Admission: the engine's reserve-at-admit
                            // relies on reserve succeeding exactly when
                            // can_grow_to says it fits.
                            let fits = p.can_grow_to(seq, amt);
                            if p.reserve(seq, amt).is_ok() != fits {
                                return false;
                            }
                        }
                        2 => {
                            // Growth accounting: re-reserve floored at the
                            // current holding — must never shrink, never
                            // fail below capacity already held.
                            let floor = p.held_by(seq) * p.page_bytes;
                            let held_before = p.held_by(seq);
                            let _ = p.reserve(seq, floor.max(amt));
                            if p.held_by(seq) < held_before {
                                return false;
                            }
                        }
                        3 => {
                            if let Ok(id) = p.publish_shared(amt) {
                                ids.push((id, 0));
                            }
                        }
                        4 => {
                            // Retain a random live holding; the pool must
                            // agree it exists exactly when we think it does
                            // (evictions are drained below each op).
                            if !ids.is_empty() {
                                let e = &mut ids[amt % ids.len()];
                                if !p.retain_shared(e.0) {
                                    return false;
                                }
                                e.1 += 1;
                            }
                        }
                        5 => {
                            if let Some(e) =
                                ids.iter_mut().filter(|e| e.1 > 0).nth(amt % 3)
                            {
                                p.release_shared(e.0);
                                e.1 -= 1;
                            }
                        }
                        6 => {
                            if let Some(e) = ids.iter_mut().find(|e| e.1 > 0) {
                                if p.cow_split(seq, e.0).is_ok() {
                                    e.1 -= 1;
                                }
                            }
                        }
                        _ => p.release(seq),
                    }
                    // Referenced holdings must never have been evicted;
                    // drop evicted unreferenced ids from the mirror.
                    for id in p.take_evicted() {
                        match ids.iter().position(|e| e.0 == id) {
                            Some(i) if ids[i].1 == 0 => {
                                ids.remove(i);
                            }
                            _ => return false,
                        }
                    }
                    // Pool refcounts must mirror ours exactly.
                    for &(id, refs) in &ids {
                        if p.shared_refs(id) != Some(refs) {
                            return false;
                        }
                    }
                    if p.check_invariants().is_err() || p.used_pages() > p.total_pages {
                        return false;
                    }
                }
                true
            },
        );
    }
}

//! Paged KV-cache pool — the vLLM-style block manager that gives the
//! coordinator admission control and backpressure over latent-cache memory.
//!
//! Backends own their storage; the pool is the *allocator of record*: every
//! sequence must reserve pages (fixed-size byte blocks) before its caches
//! may grow. When the pool is exhausted, the scheduler stops admitting new
//! sequences and queues them (backpressure), exactly like vLLM's block
//! manager refusing block allocation. Because SALS caches are `d_r`-times
//! smaller, the same pool admits proportionally more concurrent sequences —
//! the mechanism behind the Table-7 throughput gains at long contexts.
//!
//! The pool is a *ledger*, deliberately ignorant of what the bytes mean.
//! Who reserves how much is the engine's policy, and it uses the pool in
//! two modes (see the footprint contract in `crate::attention`):
//!
//! * **Admission reservation** — at admit time the engine reserves the
//!   factory's predicted footprint ([`crate::model::SequenceFootprint`])
//!   for the request's whole decode horizon, so one admission pass cannot
//!   promise the same free pages to several requests.
//! * **Growth accounting** — each step every running sequence re-reserves
//!   `max(measured kv_bytes(), admission reservation)`; the estimate is
//!   the floor, the live meter only ever raises it.

use crate::util::{Error, Result};
use std::collections::HashMap;

/// Sequence identifier used by the pool and coordinator.
pub type SeqId = u64;

/// Fixed-size-page memory pool with per-sequence accounting.
#[derive(Debug)]
pub struct PagePool {
    /// Bytes per page.
    pub page_bytes: usize,
    /// Total pages in the pool.
    pub total_pages: usize,
    free_pages: usize,
    /// Pages held per sequence.
    held: HashMap<SeqId, usize>,
    /// Peak utilization (pages), for reports.
    peak_used: usize,
}

impl PagePool {
    pub fn new(page_bytes: usize, total_pages: usize) -> PagePool {
        assert!(page_bytes > 0 && total_pages > 0);
        PagePool { page_bytes, total_pages, free_pages: total_pages, held: HashMap::new(), peak_used: 0 }
    }

    /// Pool sized for a byte budget.
    pub fn with_budget(page_bytes: usize, budget_bytes: usize) -> PagePool {
        PagePool::new(page_bytes, (budget_bytes / page_bytes).max(1))
    }

    pub fn free_pages(&self) -> usize {
        self.free_pages
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free_pages
    }

    pub fn peak_used_pages(&self) -> usize {
        self.peak_used
    }

    /// Pages needed to hold `bytes`.
    pub fn pages_for(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.page_bytes)
    }

    /// Pages currently held by a sequence.
    pub fn held_by(&self, seq: SeqId) -> usize {
        self.held.get(&seq).copied().unwrap_or(0)
    }

    /// Can `seq` grow to `target_bytes` without exceeding the pool?
    pub fn can_grow_to(&self, seq: SeqId, target_bytes: usize) -> bool {
        let need = self.pages_for(target_bytes);
        let have = self.held_by(seq);
        need <= have || need - have <= self.free_pages
    }

    /// Grow (or shrink) a sequence's reservation to cover `target_bytes`.
    /// Fails with `Error::Coordinator` when the pool is exhausted — callers
    /// translate that into scheduling backpressure.
    pub fn reserve(&mut self, seq: SeqId, target_bytes: usize) -> Result<()> {
        let need = self.pages_for(target_bytes);
        let have = self.held_by(seq);
        if need > have {
            let grow = need - have;
            if grow > self.free_pages {
                return Err(Error::Coordinator(format!(
                    "pool exhausted: seq {seq} needs {grow} pages, {} free",
                    self.free_pages
                )));
            }
            self.free_pages -= grow;
        } else {
            self.free_pages += have - need;
        }
        if need == 0 {
            self.held.remove(&seq);
        } else {
            self.held.insert(seq, need);
        }
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Release everything a finished sequence holds.
    pub fn release(&mut self, seq: SeqId) {
        if let Some(pages) = self.held.remove(&seq) {
            self.free_pages += pages;
        }
    }

    /// Invariant check: free + Σheld == total. Used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let held: usize = self.held.values().sum();
        if held + self.free_pages != self.total_pages {
            return Err(Error::Coordinator(format!(
                "pool accounting broken: held {held} + free {} != total {}",
                self.free_pages, self.total_pages
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn reserve_and_release() {
        let mut p = PagePool::new(1024, 10);
        p.reserve(1, 3000).unwrap(); // 3 pages
        assert_eq!(p.used_pages(), 3);
        p.reserve(2, 7 * 1024).unwrap();
        assert_eq!(p.free_pages(), 0);
        assert!(p.reserve(3, 1).is_err());
        p.release(1);
        assert_eq!(p.free_pages(), 3);
        p.reserve(3, 1).unwrap();
        p.check_invariants().unwrap();
    }

    #[test]
    fn grow_and_shrink_same_seq() {
        let mut p = PagePool::new(100, 10);
        p.reserve(1, 250).unwrap(); // 3 pages
        p.reserve(1, 950).unwrap(); // 10 pages
        assert_eq!(p.free_pages(), 0);
        p.reserve(1, 100).unwrap(); // shrink to 1
        assert_eq!(p.free_pages(), 9);
        p.reserve(1, 0).unwrap(); // full shrink removes entry
        assert_eq!(p.held_by(1), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn can_grow_to_is_consistent_with_reserve() {
        let mut p = PagePool::new(10, 5);
        p.reserve(1, 30).unwrap();
        assert!(p.can_grow_to(1, 50));
        assert!(!p.can_grow_to(2, 30));
        assert!(p.can_grow_to(2, 20));
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut p = PagePool::new(10, 8);
        p.reserve(1, 60).unwrap();
        p.release(1);
        assert_eq!(p.used_pages(), 0);
        assert_eq!(p.peak_used_pages(), 6);
    }

    #[test]
    fn property_random_ops_preserve_accounting() {
        // Random interleavings of the engine's three usage patterns —
        // admission-time reservation (check-then-act must agree), floored
        // growth re-reservation, and release — never break accounting and
        // never exceed capacity.
        prop::check(
            "pagepool-accounting",
            200,
            |rng: &mut Rng| {
                // encode an op sequence as raw numbers
                let n_ops = rng.range(1, 40);
                (0..n_ops * 3).map(|_| rng.below(1000)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut p = PagePool::new(16, 32);
                for chunk in ops.chunks_exact(3) {
                    let (seq, kind, amt) = (chunk[0] % 6, chunk[1] % 4, chunk[2]);
                    let seq = seq as SeqId;
                    match kind {
                        0 => {
                            let _ = p.reserve(seq, amt);
                        }
                        1 => {
                            // Admission: the engine's reserve-at-admit
                            // relies on reserve succeeding exactly when
                            // can_grow_to says it fits.
                            let fits = p.can_grow_to(seq, amt);
                            if p.reserve(seq, amt).is_ok() != fits {
                                return false;
                            }
                        }
                        2 => {
                            // Growth accounting: re-reserve floored at the
                            // current holding — must never shrink, never
                            // fail below capacity already held.
                            let floor = p.held_by(seq) * p.page_bytes;
                            let held_before = p.held_by(seq);
                            let _ = p.reserve(seq, floor.max(amt));
                            if p.held_by(seq) < held_before {
                                return false;
                            }
                        }
                        _ => p.release(seq),
                    }
                    if p.check_invariants().is_err() || p.used_pages() > p.total_pages {
                        return false;
                    }
                }
                true
            },
        );
    }
}

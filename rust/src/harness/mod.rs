//! Shared bench harness: experiment setup (retrieval model + calibration +
//! method factories) and paper-style table formatting. `benches/*.rs` are
//! thin mains over this module, so every table/figure is regenerable with
//! one `cargo bench --bench <name>`.

use crate::model::retrieval::{RetrievalModel, RetrievalSpec};
use crate::model::{
    calibrate, fit_calibration, make_factory, BackendFactory, FittedCalibration, Method, Model,
    SparsityParams,
};
use crate::util::rng::Rng;
use crate::workload::runner;
use std::sync::Arc;

/// A fully prepared accuracy experiment: constructed retrieval model,
/// calibration fitted on its own key streams, and sparsity params.
pub struct Experiment {
    pub rm: RetrievalModel,
    pub model: Model,
    pub fitted: Arc<FittedCalibration>,
    pub sp: SparsityParams,
}

impl Experiment {
    /// Build the standard experiment at a given context length. `gqa`
    /// selects the grouped-query variant of the retrieval model.
    pub fn new(ctx_len: usize, gqa: bool, seed: u64) -> Experiment {
        let spec = RetrievalSpec {
            n_keys: 48,
            n_vals: 48,
            n_fill: 64,
            max_seq: (ctx_len + 8).next_power_of_two().max(256),
            n_layers: 6,
            // Crowded value codes + realistic filler interference: makes
            // cache quantization/reconstruction noise measurable while the
            // dense baseline stays strong (see DESIGN.md §3).
            val_dim: 8,
            fill_scale: 0.5,
            alpha: 32.0,
            gqa,
            seed,
            ..Default::default()
        };
        let mut rm = RetrievalModel::build(spec);
        // Paper skips sparsification on 3 of 32 layers (~9% dense); with 6
        // layers the default {0,1,last} skip-list would make HALF the cache
        // traffic dense and floor the memory-access column at 0.5. Keep one
        // dense layer for the same ~17% proportion.
        rm.cfg.dense_layers = vec![0];
        let model = runner::retrieval_model_for(&rm);
        // §4.2 calibration on the model's own streams (mix of fillers and
        // needles so key statistics cover both populations).
        let mut rng = Rng::new(seed ^ 0xCA11B);
        let streams: Vec<Vec<usize>> = (0..6)
            .map(|_| {
                (0..128)
                    .map(|_| {
                        if rng.below(8) == 0 {
                            rm.needle_token(rng.below(rm.spec.n_keys), rng.below(rm.spec.n_vals))
                        } else {
                            rm.filler_token(rng.below(rm.spec.n_fill))
                        }
                    })
                    .collect()
            })
            .collect();
        let calib = calibrate(&model, &streams);
        let fitted = Arc::new(fit_calibration(&rm.cfg, &calib));
        let sp = SparsityParams::scaled(ctx_len);
        Experiment { rm, model, fitted, sp }
    }

    /// Backend factory for a method under this experiment's calibration.
    pub fn factory(&self, method: Method) -> Box<BackendFactory> {
        make_factory(method, &self.fitted, self.sp)
    }
}

/// Fixed-width table printer matching the paper's row/column layout.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<w$}  ", c, w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Absolute path of a bench artifact (`BENCH_*.json`) at the **repo
/// root** — the location CHANGES.md/EXPERIMENTS.md document and CI
/// uploads. Anchored on the crate manifest's parent rather than the CWD:
/// `cargo bench` runs benches from the workspace root, but `cargo bench
/// -p`, IDE runners, and CI sub-shells may not, and a CWD-relative write
/// silently scatters the perf trajectory across directories.
pub fn bench_artifact_path(file: &str) -> std::path::PathBuf {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join(file)
}

/// Machine-provenance header every `BENCH_*.json` artifact embeds: bench
/// name, the dispatched SIMD kernel tier (avx2+fma / neon / scalar), the
/// host's available thread count, and the worker-pool provenance (the
/// size `SALS_THREADS`/auto resolves to plus its measured per-dispatch
/// handoff latency in ns) — so perf trajectories recorded on different
/// machines are comparable (a scalar-tier number regressing against an
/// avx2+fma number is a hardware delta, not a code delta; likewise a
/// fan-out number measured against a 10µs spawn vs a sub-µs pool).
pub fn bench_doc(bench: &str) -> crate::util::json::Json {
    let (pool_size, pool_dispatch_ns) = crate::util::threadpool::pool_provenance();
    crate::util::json::Json::obj()
        .field("bench", bench)
        .field("simd_tier", crate::tensor::simd::tier_name())
        .field("threads_available", crate::util::threadpool::num_cpus())
        .field("pool_size", pool_size as i64)
        .field("pool_dispatch_ns", pool_dispatch_ns)
}

/// Format a fraction as "0.123".
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a percentage-like accuracy as "78.5".
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Format mean ± std (milliseconds) from seconds samples.
pub fn ms_pm(samples: &[f64]) -> String {
    let s = crate::util::stats::Summary::of(samples);
    format!("{:.3} ± {:.3}", s.mean * 1e3, s.std * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f3(0.1234), "0.123");
        assert_eq!(pct(0.785), "78.5");
    }

    #[test]
    fn bench_doc_stamps_tier_threads_and_pool() {
        let tier = crate::tensor::simd::tier_name();
        let s = bench_doc("demo").to_string();
        assert!(s.contains("\"bench\":\"demo\""), "{s}");
        assert!(s.contains(&format!("\"simd_tier\":\"{tier}\"")), "{s}");
        assert!(s.contains("\"threads_available\":"), "{s}");
        assert!(s.contains("\"pool_size\":"), "{s}");
        assert!(s.contains("\"pool_dispatch_ns\":"), "{s}");
    }
}

//! Footprint contract (estimation vs metering, see `attention/mod.rs`):
//! for EVERY method in the comparison matrix, the factory-derived
//! [`FootprintModel`] prediction at length L must track the live
//! `kv_bytes()` of a backend actually grown to L tokens within 25% —
//! the bound backend-aware admission relies on.

use sals::attention::AttentionBackend;
use sals::model::{
    calibrate, fit_calibration, make_factory, Method, Model, ModelConfig, SequenceFootprint,
    SparsityParams, Weights,
};
use sals::util::rng::Rng;
use std::sync::Arc;

/// Long enough that quantized stores are past their fp32 windows and
/// fixed terms are amortized (the models are asymptotic — they
/// deliberately over-charge very short sequences, which only makes
/// admission conservative).
const L: usize = 240;

fn all_methods() -> [Method; 12] {
    [
        Method::Full,
        Method::Sals25,
        Method::Sals125,
        Method::Kivi4,
        Method::Kivi2,
        Method::Palu30,
        Method::Palu50,
        Method::Loki,
        Method::DoubleSparse,
        Method::HShare,
        Method::Quest,
        Method::StreamingLlm,
    ]
}

fn setup() -> (ModelConfig, Arc<sals::model::FittedCalibration>) {
    let mut cfg = ModelConfig::tiny_mha(512);
    cfg.n_layers = 3;
    cfg.dense_layers = vec![0];
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 29)));
    let mut rng = Rng::new(31);
    let streams: Vec<Vec<usize>> =
        (0..2).map(|_| (0..64).map(|_| rng.below(cfg.vocab)).collect()).collect();
    let calib = calibrate(&model, &streams);
    let fitted = Arc::new(fit_calibration(&cfg, &calib));
    (cfg, fitted)
}

#[test]
fn estimate_tracks_live_kv_bytes_for_every_method() {
    let (cfg, fitted) = setup();
    let kvd = cfg.kv_dim();
    let sp = SparsityParams { sink: 2, recent: 8, critical: 8 };
    let mut rng = Rng::new(33);
    for method in all_methods() {
        let factory = make_factory(method, &fitted, sp);
        // Layer 1 is sparse (dense_layers = {0}), exercising the method's
        // own backend; layer 0 covers the dense-fallback path.
        for layer in [0usize, 1] {
            let mut b = factory(layer);
            let est = b.footprint().bytes_at(L);
            for _ in 0..L {
                let k = rng.normal_vec(kvd, 1.0);
                let v = rng.normal_vec(kvd, 1.0);
                b.append(&k, &v);
            }
            let live = b.kv_bytes();
            assert!(live > 0, "{method:?} layer {layer} ({}) metered nothing", b.name());
            let ratio = est as f64 / live as f64;
            assert!(
                (0.75..=1.25).contains(&ratio),
                "{method:?} layer {layer} ({}): estimate {est} vs live {live} (ratio {ratio:.3})",
                b.name()
            );
        }
    }
}

#[test]
fn sequence_footprint_sums_per_layer_models() {
    let (cfg, fitted) = setup();
    let sp = SparsityParams { sink: 2, recent: 8, critical: 8 };
    let factory = make_factory(Method::Sals25, &fitted, sp);
    let fp = SequenceFootprint::of(&cfg, &factory);
    assert_eq!(fp.layers().len(), cfg.n_layers);
    let by_hand: usize = (0..cfg.n_layers).map(|l| factory(l).footprint().bytes_at(L)).sum();
    assert_eq!(fp.bytes_at(L), by_hand);
    // Mixed dense/sparse layers: the dense layer 0 must be priced at the
    // dense rate, the SALS layers strictly below it.
    let dense = factory(0).footprint().bytes_at(L);
    let sparse = factory(1).footprint().bytes_at(L);
    assert!(sparse < dense, "SALS layer footprint {sparse} not below dense {dense}");
}

#[test]
fn sals_sequence_footprint_well_below_full() {
    // The serving-capacity premise (ROADMAP / Table 7): at long context a
    // SALS sequence must be priced at a fraction of dense fp32 — here
    // under 60% even with one mandatory dense layer in the mix.
    let (cfg, fitted) = setup();
    let sp = SparsityParams { sink: 2, recent: 8, critical: 8 };
    let full = SequenceFootprint::of(&cfg, &make_factory(Method::Full, &fitted, sp));
    let sals = SequenceFootprint::of(&cfg, &make_factory(Method::Sals25, &fitted, sp));
    let (f, s) = (full.bytes_at(L), sals.bytes_at(L));
    assert!(s * 10 < f * 6, "SALS {s} not well below full {f}");
}

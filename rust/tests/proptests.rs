//! Cross-module property tests: invariants that must hold for every random
//! shape/data draw, with shrinking on failure (util::prop harness).

use sals::attention::{
    merge_selection, AttentionBackend, AttnShape, FullAttention, PrefillSparsity, SalsAttention,
    SalsConfig,
};
use sals::lowrank::Calibrator;
use sals::model::{BackendFactory, BatchScratch, Model, ModelConfig, Scratch, SequenceState, Weights};
use sals::quant::{dequantize_group, quantize_group, Bits, TokenQuantStore};
use sals::rope::RopeTable;
use sals::tensor::ops::{softmax, sparse_attend, SparseAttendScratch};
use sals::tensor::{top_k_indices, Mat};
use sals::util::prop::check;
use sals::util::rng::Rng;
use sals::util::threadpool::Workers;
use std::sync::Arc;

#[test]
fn prop_rope_preserves_norm_all_shapes() {
    check(
        "rope-norm",
        150,
        |r| {
            let d = 2 * r.range(1, 32);
            let pos = r.below(256);
            let mut v = r.normal_vec(d, 1.0);
            v.push(pos as f32);
            v
        },
        |v| {
            let pos = *v.last().unwrap() as usize;
            let v = &v[..v.len() - 1];
            if v.len() < 2 || v.len() % 2 != 0 {
                return true; // shrunk into an invalid shape — vacuous
            }
            let d = v.len();
            let t = RopeTable::new(d, 256, 10_000.0);
            let mut x = v.to_vec();
            t.apply(&mut x, pos);
            let n0: f32 = v.iter().map(|a| a * a).sum();
            let n1: f32 = x.iter().map(|a| a * a).sum();
            (n0 - n1).abs() <= 1e-4 * n0.max(1.0)
        },
    );
}

#[test]
fn prop_quant_roundtrip_bounded_by_half_step() {
    check(
        "quant-halfstep",
        200,
        |r| {
            let n = r.range(1, 200);
            let scale = (r.f32() * 4.0).max(0.01);
            r.normal_vec(n, scale)
        },
        |xs| {
            for bits in [Bits::B2, Bits::B4, Bits::B8] {
                let g = quantize_group(xs, bits);
                let mut out = vec![0.0; xs.len()];
                dequantize_group(&g, &mut out);
                for (a, b) in xs.iter().zip(&out) {
                    if (a - b).abs() > g.scale * 0.5 + 1e-5 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_topk_returns_true_maxima() {
    check(
        "topk-maxima",
        200,
        |r| {
            let n = r.range(1, 300);
            let k = r.range(1, n + 1);
            let mut v = r.normal_vec(n, 1.0);
            v.push(k as f32); // smuggle k through the vec
            v
        },
        |v| {
            let k = *v.last().unwrap() as usize;
            let scores = &v[..v.len() - 1];
            let idx = top_k_indices(scores, k);
            if idx.len() != k.min(scores.len()) {
                return false;
            }
            // Every selected score >= every unselected score.
            let sel_min = idx.iter().map(|&i| scores[i]).fold(f32::INFINITY, f32::min);
            let mut unsel_max = f32::NEG_INFINITY;
            for (i, &s) in scores.iter().enumerate() {
                if !idx.contains(&i) {
                    unsel_max = unsel_max.max(s);
                }
            }
            idx.is_empty() || unsel_max == f32::NEG_INFINITY || sel_min >= unsel_max
        },
    );
}

#[test]
fn prop_merge_selection_sorted_dedup_and_bounded() {
    check(
        "merge-selection",
        200,
        |r| {
            let s = r.range(1, 200);
            let mut v: Vec<usize> = (0..r.below(20)).map(|_| r.below(s * 2)).collect();
            v.push(s); // seq len
            v.push(r.below(16)); // sink
            v.push(r.below(32)); // recent
            v
        },
        |v| {
            let n = v.len();
            let (recent, sink, s) = (v[n - 1], v[n - 2], v[n - 3]);
            let critical = &v[..n - 3];
            let sel = merge_selection(s, sink, recent, critical);
            // sorted, unique, in range
            sel.windows(2).all(|w| w[0] < w[1]) && sel.iter().all(|&i| i < s)
        },
    );
}

#[test]
fn prop_projector_columns_orthonormal_any_rank() {
    check(
        "projector-ortho",
        25,
        |r| {
            let dim = r.range(4, 24);
            let rank = r.range(1, dim + 1);
            let n = r.range(dim + 1, 80);
            let mut data = r.normal_vec(n * dim, 1.0);
            data.push(rank as f32);
            data.push(dim as f32);
            data
        },
        |data| {
            let dim = *data.last().unwrap() as usize;
            let rank = data[data.len() - 2] as usize;
            let rows = &data[..data.len() - 2];
            let mut cal = Calibrator::new(dim);
            cal.add_keys(&rows[..(rows.len() / dim) * dim]);
            let p = match cal.fit(rank) {
                Ok(p) => p,
                Err(_) => return false,
            };
            let utu = p.u.transpose().matmul(&p.u);
            for i in 0..rank {
                for j in 0..rank {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    if (utu.at(i, j) - expect).abs() > 5e-3 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// Naive per-head exact sparse attention — the per-row reference the
/// packed kernel must bit-match (≤1e-4; only fp summation order differs).
fn naive_sparse_attention(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    n_sel: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
) -> Vec<f32> {
    let kvd = n_kv_heads * d;
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n_heads * d];
    let mut scores = vec![0.0f32; n_sel];
    for h in 0..n_heads {
        let kvh = h / group;
        let qh = &q[h * d..(h + 1) * d];
        for (j, s) in scores.iter_mut().enumerate() {
            let krow = &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
            *s = qh.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
        }
        softmax(&mut scores);
        for (j, &p) in scores.iter().enumerate() {
            let vrow = &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
            for (o, &v) in out[h * d..(h + 1) * d].iter_mut().zip(vrow) {
                *o += p * v;
            }
        }
    }
    out
}

#[test]
fn prop_sparse_attend_matches_naive_reference() {
    // The packed kernel (panel packing + matmul QKᵀ/PV) must match the
    // per-row strided reference for every MHA/GQA shape draw.
    check(
        "sparse-attend-parity",
        60,
        |r| {
            let n_kv_heads = 1 << r.below(3); // 1, 2, 4
            let group = 1 << r.below(3); // MHA (1) and GQA groups
            let d = 2 * r.range(1, 9);
            let n_sel = r.range(1, 40);
            vec![n_kv_heads, group, d, n_sel, r.below(1 << 30)]
        },
        |v| {
            let (n_kv_heads, group, d, n_sel, seed) = (v[0], v[1], v[2], v[3], v[4] as u64);
            let n_heads = n_kv_heads * group;
            let kvd = n_kv_heads * d;
            let mut rng = Rng::new(seed);
            let q = rng.normal_vec(n_heads * d, 1.0);
            let keys = rng.normal_vec(n_sel * kvd, 1.0);
            let values = rng.normal_vec(n_sel * kvd, 1.0);
            let mut out = vec![0.0f32; n_heads * d];
            let mut scratch = SparseAttendScratch::default();
            sparse_attend(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d, &mut scratch, &mut out);
            let reference = naive_sparse_attention(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d);
            out.iter().zip(&reference).all(|(a, b)| (a - b).abs() < 1e-4)
        },
    );
}

#[test]
fn prop_quant_gather_rows_matches_per_row_get() {
    // Page-coherent gather ≡ per-row get() for any store shape and any
    // sorted selection spanning quant-group boundaries and the fp32 tail.
    check(
        "quant-gather-parity",
        80,
        |r| {
            let dim = r.range(1, 12);
            let group = r.range(1, 10);
            let window = r.range(1, 16);
            let len = r.range(1, 120);
            let bits = r.below(3);
            vec![dim, group, window, len, bits, r.below(1 << 30)]
        },
        |v| {
            let (dim, group, window, len, bits, seed) =
                (v[0], v[1], v[2], v[3], v[4], v[5] as u64);
            let bits = [Bits::B2, Bits::B4, Bits::B8][bits];
            let mut rng = Rng::new(seed);
            let mut st = TokenQuantStore::new(dim, bits, group, window);
            for _ in 0..len {
                st.append(&rng.normal_vec(dim, 1.0));
            }
            // Random sorted subset (keep each index with p ≈ 1/2).
            let idx: Vec<usize> = (0..len).filter(|_| rng.below(2) == 0).collect();
            let mut gathered = vec![0.0f32; idx.len() * dim];
            st.gather_rows(&idx, &mut gathered);
            let mut row = vec![0.0f32; dim];
            for (t, &j) in idx.iter().enumerate() {
                st.get(j, &mut row);
                if gathered[t * dim..(t + 1) * dim] != row[..] {
                    return false;
                }
            }
            // read_all must agree too.
            let mut all = vec![0.0f32; len * dim];
            st.read_all(&mut all);
            for j in 0..len {
                st.get(j, &mut row);
                if all[j * dim..(j + 1) * dim] != row[..] {
                    return false;
                }
            }
            true
        },
    );
}

/// SALS end-to-end decode parity: the split-panel scoring + partitioned
/// reconstruction + page-coherent value gather + packed sparse_attend
/// pipeline must match a per-row reference implementation (projector
/// project/reconstruct per row, per-row quant get(), naive per-head
/// attention) within 1e-4 — across MHA/GQA shapes, recent-ring wraps, and
/// quant-group boundaries. `critical >= len` pins the selection to the
/// whole sequence so the comparison is immune to top-k tie flips.
#[test]
fn prop_sals_pipeline_matches_per_row_reference() {
    check(
        "sals-pipeline-parity",
        12,
        |r| {
            let n_kv_heads = 1 + r.below(2); // 1 or 2
            let group = 1 + r.below(2); // MHA and GQA
            let d = 2 * r.range(2, 5); // 4..8
            let seq = r.range(12, 70); // wraps the ring (recent 8)
            vec![n_kv_heads, group, d, seq, r.below(1 << 30)]
        },
        |v| {
            let (n_kv_heads, group, d, seq, seed) = (v[0], v[1], v[2], v[3], v[4] as u64);
            let n_heads = n_kv_heads * group;
            let shape = AttnShape::gqa(n_heads, n_kv_heads, d, seq + 4);
            let kvd = shape.kv_dim();
            let mut rng = Rng::new(seed);
            let mut cal = Calibrator::new(kvd);
            for _ in 0..kvd * 4 {
                cal.add_key(&rng.normal_vec(kvd, 1.0));
            }
            let rank = (kvd / 2).max(2);
            let proj = cal.fit(rank).unwrap();
            let cfg = SalsConfig {
                rank,
                r_star: rank / 2,
                sink: 2,
                recent: 8,
                critical: seq + 4, // cover everything
                v_bits: Bits::B4,
                group: 4, // several quant pages per sequence
                prefill: None,
            };
            let mut sals = SalsAttention::new(shape, cfg.clone(), proj.clone());
            let mut store = TokenQuantStore::new(kvd, cfg.v_bits, cfg.group, cfg.recent.max(cfg.group));
            let mut keys = Vec::new();
            for _ in 0..seq {
                let k = rng.normal_vec(kvd, 1.0);
                let v = rng.normal_vec(kvd, 1.0);
                sals.append(&k, &v);
                store.append(&v);
                keys.push(k);
            }
            let q = rng.normal_vec(shape.q_dim(), 1.0);
            let mut out = vec![0.0f32; shape.q_dim()];
            sals.attend(&q, &mut out);

            // ---- per-row reference pipeline ----
            let rope = RopeTable::new(d, seq + 4, shape.rope_base);
            let recent_cap = cfg.recent.max(1);
            let mut lat = vec![0.0f32; rank];
            let mut rk = vec![0.0f32; seq * kvd];
            let mut rv = vec![0.0f32; seq * kvd];
            for (j, k) in keys.iter().enumerate() {
                let dst = &mut rk[j * kvd..(j + 1) * kvd];
                if j + recent_cap >= seq {
                    dst.copy_from_slice(k); // exact fp32 recent window
                } else {
                    proj.project(k, &mut lat);
                    proj.reconstruct(&lat, dst);
                }
                rope.apply_multihead(dst, j);
                store.get(j, &mut rv[j * kvd..(j + 1) * kvd]);
            }
            let mut qr = q.clone();
            rope.apply_multihead(&mut qr, seq - 1);
            let reference = naive_sparse_attention(&qr, &rk, &rv, seq, n_heads, n_kv_heads, d);
            out.iter().zip(&reference).all(|(a, b)| (a - b).abs() < 1e-4)
        },
    );
}

/// Block-sparse prefill parity: with τ=1.0 every block is selected, so
/// the sparse prefill path (latent block scoring + packed
/// `block_sparse_attend_chunk`) must match the dense `causal_attend_chunk`
/// fallback within 1e-4 — across MHA/GQA shapes, chunk sizes that don't
/// divide the sequence, block sizes that don't divide the cache, and
/// recent-ring/quant-group boundaries (the decode stores evolve through
/// the same push sequence on both paths).
#[test]
fn prop_block_sparse_prefill_matches_dense() {
    check(
        "block-sparse-prefill-parity",
        10,
        |r| {
            let n_kv_heads = 1 + r.below(2); // 1 or 2
            let group = 1 + r.below(2); // MHA and GQA
            let d = 2 * r.range(2, 5); // 4..8
            let seq = r.range(40, 160);
            let chunk = r.range(9, 40); // rarely divides seq
            let block = if r.below(2) == 0 { 8 } else { 16 };
            vec![n_kv_heads, group, d, seq, chunk, block, r.below(1 << 30)]
        },
        |v| {
            let (n_kv_heads, group, d, seq, chunk, block) = (v[0], v[1], v[2], v[3], v[4], v[5]);
            let seed = v[6] as u64;
            if n_kv_heads == 0
                || group == 0
                || d < 2
                || d % 2 != 0
                || seq == 0
                || chunk == 0
                || block == 0
            {
                return true; // shrunk into an invalid shape — vacuous
            }
            let n_heads = n_kv_heads * group;
            let shape = AttnShape::gqa(n_heads, n_kv_heads, d, seq + 4);
            let kvd = shape.kv_dim();
            let qd = shape.q_dim();
            let mut rng = Rng::new(seed);
            let mut cal = Calibrator::new(kvd);
            for _ in 0..kvd * 4 {
                cal.add_key(&rng.normal_vec(kvd, 1.0));
            }
            let rank = (kvd / 2).max(2);
            let proj = cal.fit(rank).unwrap();
            let mk = |min_len: usize| SalsConfig {
                rank,
                r_star: (rank / 2).max(1),
                sink: 2,
                recent: 8,
                critical: 16,
                v_bits: Bits::B4,
                group: 4, // several quant pages per sequence
                prefill: Some(PrefillSparsity { block, tau: 1.0, top_blocks: 0, min_len }),
            };
            let mut sparse = SalsAttention::new(shape, mk(0), proj.clone());
            let mut dense = SalsAttention::new(shape, mk(usize::MAX), proj);
            let mut i = 0;
            while i < seq {
                let n = chunk.min(seq - i);
                let ks = rng.normal_vec(n * kvd, 1.0);
                let vs = rng.normal_vec(n * kvd, 1.0);
                let qs = rng.normal_vec(n * qd, 1.0);
                let mut o_sparse = vec![0.0f32; n * qd];
                let mut o_dense = vec![0.0f32; n * qd];
                sparse.forward_batch(&ks, &vs, &qs, n, &mut o_sparse);
                dense.forward_batch(&ks, &vs, &qs, n, &mut o_dense);
                if !o_sparse.iter().zip(&o_dense).all(|(a, b)| (a - b).abs() < 1e-4) {
                    return false;
                }
                i += n;
            }
            true
        },
    );
}

/// Block-sparse prefill thread invariance (mirror of
/// `fused_attend_output_is_thread_invariant` for the prefill path): the
/// per-KV-head lane fan-out and the block score scan use fixed
/// decompositions, so any worker count must produce BIT-identical chunk
/// outputs — including at a τ that selects a strict subset of blocks.
#[test]
fn prop_block_sparse_prefill_is_thread_invariant() {
    check(
        "block-sparse-prefill-threads",
        6,
        |r| {
            let n_kv_heads = 1 + r.below(3); // 1..3
            let d = 2 * r.range(2, 5);
            let seq = r.range(48, 140);
            vec![n_kv_heads, d, seq, r.below(1 << 30)]
        },
        |v| {
            let (n_kv_heads, d, seq, seed) = (v[0], v[1], v[2], v[3] as u64);
            if n_kv_heads == 0 || d < 2 || d % 2 != 0 || seq == 0 {
                return true;
            }
            let n_heads = n_kv_heads * 2;
            let shape = AttnShape::gqa(n_heads, n_kv_heads, d, seq + 4);
            let kvd = shape.kv_dim();
            let qd = shape.q_dim();
            let mut rng = Rng::new(seed);
            let mut cal = Calibrator::new(kvd);
            for _ in 0..kvd * 4 {
                cal.add_key(&rng.normal_vec(kvd, 1.0));
            }
            let rank = (kvd / 2).max(2);
            let proj = cal.fit(rank).unwrap();
            let cfg = SalsConfig {
                rank,
                r_star: (rank / 2).max(1),
                sink: 2,
                recent: 8,
                critical: 16,
                v_bits: Bits::B4,
                group: 4,
                prefill: Some(PrefillSparsity { block: 8, tau: 0.6, top_blocks: 0, min_len: 0 }),
            };
            let chunk = 31; // doesn't divide seq
            let mut chunks: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = Vec::new();
            let mut i = 0;
            while i < seq {
                let n = chunk.min(seq - i);
                chunks.push((
                    rng.normal_vec(n * kvd, 1.0),
                    rng.normal_vec(n * kvd, 1.0),
                    rng.normal_vec(n * qd, 1.0),
                ));
                i += n;
            }
            let run = |workers: &Workers| {
                let mut b = SalsAttention::new(shape, cfg.clone(), proj.clone());
                b.set_workers(workers);
                let mut outs = Vec::new();
                for (ks, vs, qs) in &chunks {
                    let n = ks.len() / kvd;
                    let mut o = vec![0.0f32; n * qd];
                    b.forward_batch(ks, vs, qs, n, &mut o);
                    outs.extend_from_slice(&o);
                }
                outs
            };
            let base = run(&Workers::serial());
            [Workers::scoped(3), Workers::scoped(8), Workers::pooled(3), Workers::pooled(8)]
                .iter()
                .all(|w| run(w) == base)
        },
    );
}

/// Fused-vs-staged decode parity: the production fused pipeline (tiled
/// reconstruct·RoPE·QKᵀ with online softmax, per-head value slices) must
/// match the PR-4 staged reference (materialized key panel + packed
/// `sparse_attend`) within 1e-4 on the same state — across MHA/GQA
/// shapes, ranks with a non-empty remainder panel, recent-ring wraps, and
/// quant-group boundaries. Unlike the per-row-reference proptest this one
/// keeps top-k selection ACTIVE (both paths share stages 1–2, so the
/// selection is identical by construction and tie flips cannot diverge
/// the comparison).
#[test]
fn prop_fused_attend_matches_staged_pipeline() {
    check(
        "sals-fused-vs-staged",
        12,
        |r| {
            let n_kv_heads = 1 + r.below(3); // 1..3 (non-power-of-two too)
            let group = 1 + r.below(2); // MHA and GQA
            let d = 2 * r.range(2, 5); // 4..8
            let seq = r.range(12, 90); // wraps the ring (recent 8)
            let critical = r.range(2, 20);
            vec![n_kv_heads, group, d, seq, critical, r.below(1 << 30)]
        },
        |v| {
            let (n_kv_heads, group, d, seq, critical, seed) =
                (v[0], v[1], v[2], v[3], v[4], v[5] as u64);
            let n_heads = n_kv_heads * group;
            let shape = AttnShape::gqa(n_heads, n_kv_heads, d, seq + 4);
            let kvd = shape.kv_dim();
            let mut rng = Rng::new(seed);
            let mut cal = Calibrator::new(kvd);
            for _ in 0..kvd * 4 {
                cal.add_key(&rng.normal_vec(kvd, 1.0));
            }
            let rank = (kvd / 2).max(2);
            let cfg = SalsConfig {
                rank,
                r_star: (rank / 2).max(1), // remainder panel non-empty
                sink: 2,
                recent: 8,
                critical,
                v_bits: Bits::B4,
                group: 4, // several quant pages per sequence
                prefill: None,
            };
            let proj = cal.fit(rank).unwrap();
            let mut fused = SalsAttention::new(shape, cfg.clone(), proj.clone());
            let mut staged = SalsAttention::new(shape, cfg, proj);
            for _ in 0..seq {
                let k = rng.normal_vec(kvd, 1.0);
                let v = rng.normal_vec(kvd, 1.0);
                fused.append(&k, &v);
                staged.append(&k, &v);
            }
            let q = rng.normal_vec(shape.q_dim(), 1.0);
            let mut of = vec![0.0f32; shape.q_dim()];
            let mut os = vec![0.0f32; shape.q_dim()];
            fused.attend(&q, &mut of);
            staged.attend_staged(&q, &mut os);
            of.iter().zip(&os).all(|(a, b)| (a - b).abs() < 1e-4)
        },
    );
}

#[test]
fn prop_sals_attend_finite_and_deterministic() {
    // For any shape draw, SALS attend must be finite and reproducible.
    check(
        "sals-finite",
        20,
        |r| {
            let heads = 1 << r.below(3); // 1,2,4
            let dim = 2 * r.range(2, 9); // even 4..16
            let seq = r.range(3, 60);
            vec![heads, dim, seq, r.below(1 << 30)]
        },
        |v| {
            let (heads, dim, seq, seed) = (v[0], v[1], v[2], v[3] as u64);
            let shape = AttnShape::mha(heads, dim, seq + 4);
            let kvd = shape.kv_dim();
            let mut rng = Rng::new(seed);
            let mut cal = Calibrator::new(kvd);
            for _ in 0..kvd * 2 {
                cal.add_key(&rng.normal_vec(kvd, 1.0));
            }
            let rank = (kvd / 2).max(1);
            let proj = cal.fit(rank).unwrap();
            let cfg = SalsConfig {
                rank,
                r_star: (rank / 2).max(1),
                sink: 1,
                recent: 2,
                critical: 4,
                v_bits: Bits::B4,
                group: 4,
                prefill: None,
            };
            let run = |seed2: u64| {
                let mut rng = Rng::new(seed2);
                let mut b = SalsAttention::new(shape, cfg.clone(), proj.clone());
                for _ in 0..seq {
                    let k = rng.normal_vec(kvd, 1.0);
                    let vv = rng.normal_vec(kvd, 1.0);
                    b.append(&k, &vv);
                }
                let q = rng.normal_vec(shape.q_dim(), 1.0);
                let mut out = vec![0.0f32; shape.q_dim()];
                b.attend(&q, &mut out);
                out
            };
            let a = run(seed ^ 1);
            let b = run(seed ^ 1);
            a == b && a.iter().all(|x| x.is_finite())
        },
    );
}

#[test]
fn prop_full_attention_is_convex_combination_of_values() {
    // Output of each head must lie within the convex hull of cached values
    // per dimension (softmax weights sum to 1).
    check(
        "full-attn-hull",
        30,
        |r| {
            let dim = 2 * r.range(2, 9);
            let seq = r.range(1, 40);
            vec![dim, seq, r.below(1 << 30)]
        },
        |v| {
            let (dim, seq, seed) = (v[0], v[1], v[2] as u64);
            let shape = AttnShape::mha(1, dim, seq + 2);
            let mut rng = Rng::new(seed);
            let mut b = FullAttention::new(shape);
            let mut vals = Vec::new();
            for _ in 0..seq {
                let k = rng.normal_vec(dim, 1.0);
                let vv = rng.normal_vec(dim, 1.0);
                vals.push(vv.clone());
                b.append(&k, &vv);
            }
            let q = rng.normal_vec(dim, 1.0);
            let mut out = vec![0.0f32; dim];
            b.attend(&q, &mut out);
            for c in 0..dim {
                let lo = vals.iter().map(|v| v[c]).fold(f32::INFINITY, f32::min);
                let hi = vals.iter().map(|v| v[c]).fold(f32::NEG_INFINITY, f32::max);
                if out[c] < lo - 1e-3 || out[c] > hi + 1e-3 {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_eig_reconstruction_any_symmetric() {
    check(
        "eig-reconstruct",
        20,
        |r| {
            let d = r.range(2, 12);
            let mut v = r.normal_vec(d * d, 1.0);
            v.push(d as f32);
            v
        },
        |v| {
            let d = *v.last().unwrap() as usize;
            let b = Mat::from_vec(d, d, v[..d * d].to_vec());
            let a = b.matmul_t(&b); // symmetric PSD
            let e = sals::linalg::eig_symmetric(&a, 60, 1e-10);
            // Verify A·v_j = λ_j·v_j for the leading eigenpair.
            let mut av = vec![0.0f32; d];
            for i in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += a.at(i, k) * e.vectors.at(k, 0);
                }
                av[i] = s;
            }
            let norm_a = a.fro_norm() as f32;
            for i in 0..d {
                if (av[i] - e.values[0] * e.vectors.at(i, 0)).abs() > 1e-3 * norm_a.max(1.0) {
                    return false;
                }
            }
            true
        },
    );
}

/// Cross-sequence batched decode ≡ independent scalar decode: for random
/// per-sequence prompts, one `Model::decode_batch` over k sequences must
/// match k independent `step()` calls within 1e-4, for batch sizes
/// {1, 2, 5}, several consecutive decode steps (scratch reuse), and both
/// the FullAttention and SalsAttention backends.
///
/// As in the prefill proptest, the SALS config keeps `critical` ≥ sequence
/// length so the comparison is immune to top-k order flips; the latent
/// store, recent-key ring, and quantized value store are fully exercised.
#[test]
fn prop_decode_batch_matches_step_loop() {
    let cfg = ModelConfig::tiny_gqa(96);
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 57)));
    let shape = cfg.attn_shape();
    let kvd = cfg.kv_dim();

    let mut crng = Rng::new(63);
    let mut cal = Calibrator::new(kvd);
    for _ in 0..200 {
        cal.add_key(&crng.normal_vec(kvd, 1.0));
    }
    let proj = cal.fit(kvd / 2).unwrap();
    let sals_cfg = SalsConfig {
        rank: kvd / 2,
        r_star: kvd / 4,
        sink: 2,
        recent: 8,
        critical: 64,
        v_bits: Bits::B4,
        group: 8,
        prefill: None,
    };

    let full: Box<BackendFactory> =
        Box::new(move |_| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>);
    let sals: Box<BackendFactory> = {
        let (p, c) = (proj, sals_cfg);
        Box::new(move |_| {
            Box::new(SalsAttention::new(shape, c.clone(), p.clone())) as Box<dyn AttentionBackend + Send>
        })
    };

    let mut rng = Rng::new(65);
    for (name, factory) in [("full", &full), ("sals", &sals)] {
        for &batch in &[1usize, 2, 5] {
            // Per-sequence random prompts and decode tokens (3 steps).
            let prompts: Vec<Vec<usize>> = (0..batch)
                .map(|_| (0..1 + rng.below(20)).map(|_| rng.below(cfg.vocab)).collect())
                .collect();
            let steps: Vec<Vec<usize>> =
                (0..batch).map(|_| (0..3).map(|_| rng.below(cfg.vocab)).collect()).collect();

            // Reference: each sequence decoded independently via step().
            let mut ref_logits: Vec<Vec<Vec<f32>>> = Vec::new();
            for (p, toks) in prompts.iter().zip(&steps) {
                let mut state = SequenceState::new(&cfg, factory);
                let mut sc = Scratch::new(&cfg);
                model.prefill(&mut state, &mut sc, p);
                ref_logits
                    .push(toks.iter().map(|&t| model.step(&mut state, &mut sc, t, true).unwrap()).collect());
            }

            // Batched: same prompts, one decode_batch per step, shared
            // (reused) BatchScratch across steps.
            let mut states: Vec<SequenceState> = prompts
                .iter()
                .map(|p| {
                    let mut s = SequenceState::new(&cfg, factory);
                    let mut sc = Scratch::new(&cfg);
                    model.prefill(&mut s, &mut sc, p);
                    s
                })
                .collect();
            let mut bs = BatchScratch::new(2);
            for step in 0..3 {
                let tokens: Vec<usize> = steps.iter().map(|s| s[step]).collect();
                let mut refs: Vec<&mut SequenceState> = states.iter_mut().collect();
                let logits = model.decode_batch(&mut refs, &tokens, &mut bs);
                for (i, l) in logits.iter().enumerate() {
                    for (a, b) in l.iter().zip(&ref_logits[i][step]) {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{name} batch {batch} step {step} seq {i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }
}

/// Fork/adopt bit-identity: adopting a forked prefix and prefilling only
/// the suffix must reproduce the cold run EXACTLY — bit-equal logits at
/// every step and equal `kv_bytes()` — for both the FullAttention and
/// SalsAttention backends. Draws cover recent-ring wraps and quant-page
/// boundaries (prefix lengths both aligned and misaligned to the quant
/// group), always forking at a chunk multiple (the engine's publication
/// contract), and keep SALS top-k selection ACTIVE: adopted state is
/// bit-equal, so scores — and therefore the selected set — are identical
/// by construction, not by tolerance.
#[test]
fn prop_fork_adopt_decode_bit_identical_to_cold() {
    let cfg = ModelConfig::tiny_gqa(96);
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 91)));
    let shape = cfg.attn_shape();
    let kvd = cfg.kv_dim();

    let mut crng = Rng::new(93);
    let mut cal = Calibrator::new(kvd);
    for _ in 0..200 {
        cal.add_key(&crng.normal_vec(kvd, 1.0));
    }
    let proj = cal.fit(kvd / 2).unwrap();
    let sals_cfg = SalsConfig {
        rank: kvd / 2,
        r_star: kvd / 4,
        sink: 2,
        recent: 8,   // prefixes below wrap the ring
        critical: 12, // strict subset of the sequence — selection stays live
        v_bits: Bits::B4,
        group: 8, // quant-page boundary every 8 tokens
        prefill: None,
    };

    let full: Box<BackendFactory> =
        Box::new(move |_| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>);
    let sals: Box<BackendFactory> = {
        let (p, c) = (proj, sals_cfg);
        Box::new(move |_| {
            Box::new(SalsAttention::new(shape, c.clone(), p.clone())) as Box<dyn AttentionBackend + Send>
        })
    };

    let mut rng = Rng::new(95);
    for (name, factory) in [("full", &full), ("sals", &sals)] {
        for case in 0..6 {
            let chunk = 3 + rng.below(8); // 3..=10
            let prefix_len = chunk * (2 + rng.below(4)); // 6..=50, chunk-aligned
            let suffix_len = 1 + rng.below(2 * chunk);
            let prompt: Vec<usize> =
                (0..prefix_len + suffix_len).map(|_| rng.below(cfg.vocab)).collect();
            let dec: Vec<usize> = (0..3).map(|_| rng.below(cfg.vocab)).collect();
            let ctx = format!("{name} case {case} chunk {chunk} prefix {prefix_len} suffix {suffix_len}");

            // Cold run: whole prompt prefilled in one schedule.
            let mut s_cold = SequenceState::new(&cfg, factory);
            let mut sc_cold = Scratch::new(&cfg);
            let mut cold = vec![model.prefill_chunked(&mut s_cold, &mut sc_cold, &prompt, chunk)];
            for &t in &dec {
                cold.push(model.step(&mut s_cold, &mut sc_cold, t, true).unwrap());
            }

            // Donor: prefill only the prefix (same chunk schedule as the
            // cold run's first `prefix_len` tokens), then freeze it.
            let mut donor = SequenceState::new(&cfg, factory);
            let mut sc = Scratch::new(&cfg);
            model.prefill_chunked(&mut donor, &mut sc, &prompt[..prefix_len], chunk);
            let snap = donor.fork_prefix(prefix_len).unwrap_or_else(|| panic!("{ctx}: fork refused"));
            assert!(snap.shared_bytes() > 0, "{ctx}: empty snapshot");

            // Warm run: adopt the snapshot, prefill only the suffix. The
            // boundary is a chunk multiple, so the suffix chunks land on
            // the cold run's boundaries — identical arithmetic throughout.
            let mut s_warm = SequenceState::new(&cfg, factory);
            let mut sc_warm = Scratch::new(&cfg);
            assert!(s_warm.adopt_prefix(&snap), "{ctx}: adoption refused");
            assert!(s_warm.shared_prefix_bytes() > 0, "{ctx}: adopter holds no shared bytes");
            let mut warm =
                vec![model.prefill_chunked(&mut s_warm, &mut sc_warm, &prompt[prefix_len..], chunk)];
            for &t in &dec {
                warm.push(model.step(&mut s_warm, &mut sc_warm, t, true).unwrap());
            }

            assert_eq!(s_warm.pos, s_cold.pos, "{ctx}: position drift");
            assert_eq!(s_warm.kv_bytes(), s_cold.kv_bytes(), "{ctx}: kv_bytes drift");
            for (step, (w, c)) in warm.iter().zip(&cold).enumerate() {
                assert!(w == c, "{ctx}: logits differ at step {step}");
            }
        }
    }
}

/// Batched prefill ≡ sequential decode: for random prompts and every
/// chunking (including 1 and the whole prompt), `Model::prefill_chunked`
/// must reproduce the `step()` loop's logits within 1e-4, for both the
/// FullAttention and SalsAttention backends.
///
/// The SALS config keeps `critical` ≥ prompt length so the comparison is
/// immune to top-k order flips from the batched projection's ~1e-7 fp
/// reordering (the selection *set* is then identical by construction);
/// the latent store, recent-key ring, and quantized value store are still
/// fully exercised, including ring wrap-around.
#[test]
fn prop_batched_prefill_matches_step_loop() {
    let cfg = ModelConfig::tiny_gqa(96);
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 29)));
    let shape = cfg.attn_shape();
    let kvd = cfg.kv_dim();

    // SALS projector calibrated on random keys (full exercise of the
    // project→select→reconstruct pipeline; exactness of the projector is
    // irrelevant here because both paths share it).
    let mut crng = Rng::new(31);
    let mut cal = Calibrator::new(kvd);
    for _ in 0..200 {
        cal.add_key(&crng.normal_vec(kvd, 1.0));
    }
    let proj = cal.fit(kvd / 2).unwrap();
    let sals_cfg = SalsConfig {
        rank: kvd / 2,
        r_star: kvd / 4,
        sink: 2,
        recent: 8,
        critical: 64,
        v_bits: Bits::B4,
        group: 8,
        prefill: None,
    };

    let full: Box<BackendFactory> =
        Box::new(move |_| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>);
    let sals: Box<BackendFactory> = {
        let (p, c) = (proj, sals_cfg);
        Box::new(move |_| {
            Box::new(SalsAttention::new(shape, c.clone(), p.clone())) as Box<dyn AttentionBackend + Send>
        })
    };

    let mut rng = Rng::new(33);
    for (name, factory) in [("full", &full), ("sals", &sals)] {
        for case in 0..5 {
            let len = 1 + rng.below(30);
            let tokens: Vec<usize> = (0..len).map(|_| rng.below(cfg.vocab)).collect();

            // Sequential reference: the token-at-a-time decode loop.
            let mut s_ref = SequenceState::new(&cfg, factory);
            let mut sc_ref = Scratch::new(&cfg);
            let mut reference = None;
            for (i, &t) in tokens.iter().enumerate() {
                reference = model.step(&mut s_ref, &mut sc_ref, t, i == tokens.len() - 1);
            }
            let reference = reference.unwrap();

            for chunk in [1usize, 2, 5, len] {
                let mut s = SequenceState::new(&cfg, factory);
                let mut sc = Scratch::new(&cfg);
                let logits = model.prefill_chunked(&mut s, &mut sc, &tokens, chunk);
                assert_eq!(s.pos, len, "{name} case {case} chunk {chunk}: bad position");
                assert_eq!(s.kv_bytes(), s_ref.kv_bytes(), "{name} case {case} chunk {chunk}: cache size");
                for (a, b) in logits.iter().zip(&reference) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "{name} case {case} chunk {chunk} len {len}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

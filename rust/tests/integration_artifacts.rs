//! Integration: rust loads the python-lowered HLO artifacts and decodes.
//!
//! Skips (with a loud message) when `artifacts/` hasn't been built — run
//! `make artifacts` first — or when the crate was built without the `xla`
//! feature (default offline build: the PJRT runtime is a stub).

use sals::runtime::{ArtifactRuntime, XlaModel, XlaVariant};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if cfg!(not(feature = "xla")) {
        eprintln!("SKIP: built without the `xla` feature (PJRT runtime stubbed)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn sals_decode_runs_and_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).unwrap();
    let mut m1 = XlaModel::new(&mut rt, &dir, XlaVariant::Sals).unwrap();
    let out1 = m1.generate(&rt, &[1, 2, 3, 4, 5], 8).unwrap();
    let mut m2 = XlaModel::new(&mut rt, &dir, XlaVariant::Sals).unwrap();
    let out2 = m2.generate(&rt, &[1, 2, 3, 4, 5], 8).unwrap();
    assert_eq!(out1, out2);
    assert_eq!(out1.len(), 8);
    assert!(out1.iter().all(|&t| t < m1.meta.vocab));
}

#[test]
fn dense_decode_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).unwrap();
    let mut m = XlaModel::new(&mut rt, &dir, XlaVariant::Dense).unwrap();
    let out = m.generate(&rt, &[7, 8, 9], 4).unwrap();
    assert_eq!(out.len(), 4);
}

#[test]
fn sals_and_dense_agree_on_short_prompts() {
    // With seq << k_sel the selection covers every token, so the only gap
    // between SALS and dense is the rank-r latent reconstruction error.
    // Logits must be strongly correlated.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).unwrap();
    let mut sals = XlaModel::new(&mut rt, &dir, XlaVariant::Sals).unwrap();
    let mut dense = XlaModel::new(&mut rt, &dir, XlaVariant::Dense).unwrap();
    let prompt = [3usize, 14, 15, 9, 26, 5];
    let mut l_sals = Vec::new();
    let mut l_dense = Vec::new();
    for &t in &prompt {
        l_sals = sals.step(&rt, t).unwrap();
        l_dense = dense.step(&rt, t).unwrap();
    }
    let cos = sals::util::stats::cosine(&l_sals, &l_dense);
    assert!(cos > 0.7, "SALS/dense logit cosine {cos}");
}

#[test]
fn reset_clears_state() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).unwrap();
    let mut m = XlaModel::new(&mut rt, &dir, XlaVariant::Sals).unwrap();
    let a = m.generate(&rt, &[2, 4, 6], 3).unwrap();
    m.reset();
    assert_eq!(m.pos, 0);
    let b = m.generate(&rt, &[2, 4, 6], 3).unwrap();
    assert_eq!(a, b);
}

#[test]
fn standalone_kernel_artifacts_load() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).unwrap();
    rt.load("latent_score").unwrap();
    rt.load("sparse_attn").unwrap();
    assert!(rt.loaded().len() >= 2);
}
